//! The task system: stateful tasks, pull-scheduled workers, and two
//! scheduling engines — selected by *capability negotiation* against the
//! injected compute manager, never by naming a concrete backend.
//!
//! `TaskSystem::new` accepts any [`ComputeManager`] trait object:
//!
//! - If the manager's execution states support cooperative suspension
//!   (`supports_suspension()`, e.g. the fiber-class `coro` plugin), tasks
//!   run on the **parking scheduler**: pull-loop workers drive states
//!   with [`ExecutionState::resume`], and a task waiting on children
//!   parks *without* occupying its worker.
//! - Otherwise (run-to-completion states, e.g. the `threads` or `nosv`
//!   plugins) tasks run on the **blocking scheduler**: a dispatcher
//!   admits queued tasks into `n_workers` concurrency slots and runs
//!   each on its own processing unit; waiting on children blocks the
//!   kernel thread after releasing its slot.
//!
//! The paper's Test Case 3/4 engine comparison (Boost fibers vs nOS-V
//! thread-per-task) is therefore a pure backend swap: the same
//! application body runs under `--compute coro` or `--compute nosv`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::core::compute::{
    ComputeManager, ExecStatus, ExecutionState, ExecutionUnit, FnExecutionUnit,
    ProcessingUnit,
};
use crate::core::error::{HicrError, Result};
use crate::core::ids::ComputeResourceId;
use crate::core::topology::ComputeResource;
use crate::frontends::tasking::trace::{EventKind, Trace};

/// Which scheduling engine drives the tasks — derived from the compute
/// manager's capabilities, not chosen by the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EngineKind {
    /// Suspendable states: pull workers + user-level parking.
    Suspending,
    /// Run-to-completion states: slot-gated dispatch, blocking waits.
    Blocking,
}

/// A task body: runs once, may spawn children and wait for them.
pub type TaskBody = Box<dyn FnOnce(&TaskCtx) + Send + 'static>;

/// Dependency/lifecycle bookkeeping shared by both engines.
struct TaskSync {
    pending_children: usize,
    waiting: bool,
    /// Set when a waiting parent became ready before it finished parking.
    ready_now: bool,
    /// Parked suspendable task awaiting child completion.
    parked: Option<SuspendableTask>,
}

struct TaskNode {
    #[allow(dead_code)]
    id: u64,
    label: String,
    parent: Option<Arc<TaskNode>>,
    sync: Mutex<TaskSync>,
    /// Blocking engine: parents block here awaiting children.
    cv: Condvar,
}

/// A task bound to a suspendable execution state (parking scheduler).
#[derive(Clone)]
struct SuspendableTask {
    node: Arc<TaskNode>,
    state: Arc<dyn ExecutionState>,
}

/// Counting semaphore handing out stable slot ids (blocking-engine
/// concurrency slots).
struct IdSemaphore {
    free: Mutex<Vec<usize>>,
    cv: Condvar,
}

impl IdSemaphore {
    fn new(n: usize) -> Self {
        Self {
            free: Mutex::new((0..n).rev().collect()),
            cv: Condvar::new(),
        }
    }

    fn acquire(&self) -> usize {
        let mut free = self.free.lock().unwrap();
        loop {
            if let Some(id) = free.pop() {
                return id;
            }
            free = self.cv.wait(free).unwrap();
        }
    }

    fn release(&self, id: usize) {
        self.free.lock().unwrap().push(id);
        self.cv.notify_one();
    }
}

struct SuspendingEngine {
    ready: Mutex<VecDeque<SuspendableTask>>,
    ready_cv: Condvar,
    shutdown: AtomicBool,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

struct BlockingEngine {
    slots: IdSemaphore,
    /// Submitted-but-unscheduled tasks. Thread-per-task backends
    /// materialize a task's kernel thread when it is *scheduled*, not
    /// when submitted — eager per-submission spawning would hold
    /// thousands of live threads on a deep DAG (observed as EAGAIN at
    /// F(20); EXPERIMENTS.md §Perf).
    queue: Mutex<VecDeque<(TaskBody, Arc<TaskNode>)>>,
    queue_cv: Condvar,
    shutdown: AtomicBool,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Processing units with live states, garbage-collected as their
    /// states finish (terminating a unit joins its executor).
    live: Mutex<Vec<(Arc<dyn ProcessingUnit>, Arc<dyn ExecutionState>)>>,
}

struct Inner {
    cm: Arc<dyn ComputeManager>,
    engine: EngineKind,
    trace: Arc<Trace>,
    next_task_id: AtomicU64,
    outstanding: AtomicUsize,
    done_mx: Mutex<()>,
    done_cv: Condvar,
    tasks_executed: AtomicU64,
    /// First task the backend rejected (wrong unit format, terminated
    /// unit): surfaced as the error of the enclosing `run()` so a
    /// mis-selected backend fails loudly instead of reporting wrong
    /// results.
    first_error: Mutex<Option<HicrError>>,
    suspending: Option<SuspendingEngine>,
    blocking: Option<BlockingEngine>,
}

/// Handle task bodies use to spawn children and synchronize (the only
/// API the Fibonacci/Jacobi apps see — engine-independent).
pub struct TaskCtx<'a> {
    inner: &'a Arc<Inner>,
    node: &'a Arc<TaskNode>,
    exec: Option<&'a crate::core::compute::ExecCtx<'a>>,
}

impl<'a> TaskCtx<'a> {
    /// Spawn a child task. The child may itself spawn and wait.
    pub fn spawn(&self, label: impl Into<String>, body: impl FnOnce(&TaskCtx) + Send + 'static) {
        {
            let mut sync = self.node.sync.lock().unwrap();
            sync.pending_children += 1;
        }
        spawn_task(
            self.inner,
            label.into(),
            Box::new(body),
            Some(Arc::clone(self.node)),
        );
    }

    /// Wait until every child spawned by this task has finished.
    pub fn wait_children(&self) {
        match self.inner.engine {
            EngineKind::Suspending => {
                // Park the state; child completion re-enqueues us.
                loop {
                    {
                        let mut sync = self.node.sync.lock().unwrap();
                        if sync.pending_children == 0 {
                            return;
                        }
                        sync.waiting = true;
                    }
                    self.exec
                        .expect("suspending task without exec ctx")
                        .suspend();
                }
            }
            EngineKind::Blocking => {
                // Release our concurrency slot and block the kernel
                // thread.
                let engine = self.inner.blocking.as_ref().expect("blocking engine");
                let slot = current_task_slot();
                if let Some(s) = slot {
                    engine.slots.release(s);
                }
                {
                    let mut sync = self.node.sync.lock().unwrap();
                    while sync.pending_children > 0 {
                        sync = self.node.cv.wait(sync).unwrap();
                    }
                }
                if slot.is_some() {
                    let s = engine.slots.acquire();
                    set_task_slot(Some(s));
                }
            }
        }
    }
}

thread_local! {
    /// The blocking-engine concurrency slot the current task thread holds.
    static TASK_SLOT: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

fn current_task_slot() -> Option<usize> {
    TASK_SLOT.with(|s| s.get())
}

fn set_task_slot(v: Option<usize>) {
    TASK_SLOT.with(|s| s.set(v));
}

/// The task system facade.
pub struct TaskSystem {
    inner: Arc<Inner>,
    n_workers: usize,
}

impl TaskSystem {
    /// Create a system with `n_workers` workers/slots executing through
    /// `cm`. Any compute manager whose execution units are host closures
    /// works; the scheduling engine is negotiated from the manager's
    /// suspension capability.
    pub fn new(
        cm: Arc<dyn ComputeManager>,
        n_workers: usize,
        trace_enabled: bool,
    ) -> Arc<TaskSystem> {
        assert!(n_workers > 0, "need at least one worker");
        let engine = if cm.supports_suspension() {
            EngineKind::Suspending
        } else {
            EngineKind::Blocking
        };
        let trace = Arc::new(Trace::new(trace_enabled));
        let inner = Arc::new(Inner {
            cm,
            engine,
            trace,
            next_task_id: AtomicU64::new(1),
            outstanding: AtomicUsize::new(0),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            tasks_executed: AtomicU64::new(0),
            first_error: Mutex::new(None),
            suspending: match engine {
                EngineKind::Suspending => Some(SuspendingEngine {
                    ready: Mutex::new(VecDeque::new()),
                    ready_cv: Condvar::new(),
                    shutdown: AtomicBool::new(false),
                    workers: Mutex::new(Vec::new()),
                }),
                EngineKind::Blocking => None,
            },
            blocking: match engine {
                EngineKind::Blocking => Some(BlockingEngine {
                    slots: IdSemaphore::new(n_workers),
                    queue: Mutex::new(VecDeque::new()),
                    queue_cv: Condvar::new(),
                    shutdown: AtomicBool::new(false),
                    dispatcher: Mutex::new(None),
                    live: Mutex::new(Vec::new()),
                }),
                EngineKind::Suspending => None,
            },
        });
        if engine == EngineKind::Blocking {
            // The system-wide scheduler pump: admits queued tasks onto
            // processing units as slots free up.
            let inner2 = Arc::clone(&inner);
            let handle = std::thread::Builder::new()
                .name("hicr-task-sched".into())
                .spawn(move || blocking_dispatcher_loop(inner2))
                .expect("spawn task dispatcher");
            *inner.blocking.as_ref().unwrap().dispatcher.lock().unwrap() = Some(handle);
        }
        if engine == EngineKind::Suspending {
            // Start the pull-loop workers (paper: "a simple loop that
            // calls a pull function").
            let eng = inner.suspending.as_ref().unwrap();
            let mut workers = eng.workers.lock().unwrap();
            for w in 0..n_workers {
                let inner2 = Arc::clone(&inner);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("hicr-task-worker-{w}"))
                        .spawn(move || suspending_worker_loop(inner2, w))
                        .expect("spawn task worker"),
                );
            }
        }
        Arc::new(TaskSystem { inner, n_workers })
    }

    /// The backend executing the tasks.
    pub fn backend_name(&self) -> &'static str {
        self.inner.cm.backend_name()
    }

    /// True when the parking (user-level suspension) scheduler is active.
    pub fn suspending(&self) -> bool {
        self.inner.engine == EngineKind::Suspending
    }

    pub fn trace(&self) -> Arc<Trace> {
        Arc::clone(&self.inner.trace)
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Tasks executed to completion so far.
    pub fn tasks_executed(&self) -> u64 {
        self.inner.tasks_executed.load(Ordering::Relaxed)
    }

    /// Spawn a root task and block until the whole task graph quiesces.
    /// Fails if the backend rejected any task (e.g. a compute plugin
    /// that does not prescribe host-closure execution units).
    pub fn run(&self, label: impl Into<String>, body: impl FnOnce(&TaskCtx) + Send + 'static) -> Result<()> {
        spawn_task(&self.inner, label.into(), Box::new(body), None);
        let mut guard = self.inner.done_mx.lock().unwrap();
        while self.inner.outstanding.load(Ordering::Acquire) != 0 {
            guard = self.inner.done_cv.wait(guard).unwrap();
        }
        drop(guard);
        if let Some(e) = self.inner.first_error.lock().unwrap().take() {
            return Err(e);
        }
        Ok(())
    }

    /// Stop workers (suspending) / the scheduler pump (blocking). Call
    /// after the last `run`.
    pub fn shutdown(&self) -> Result<()> {
        if let Some(engine) = &self.inner.suspending {
            engine.shutdown.store(true, Ordering::SeqCst);
            engine.ready_cv.notify_all();
            let mut workers = engine.workers.lock().unwrap();
            for w in workers.drain(..) {
                w.join()
                    .map_err(|_| HicrError::InvalidState("task worker panicked".into()))?;
            }
        }
        if let Some(engine) = &self.inner.blocking {
            engine.shutdown.store(true, Ordering::SeqCst);
            engine.queue_cv.notify_all();
            if let Some(d) = engine.dispatcher.lock().unwrap().take() {
                d.join()
                    .map_err(|_| HicrError::InvalidState("task dispatcher panicked".into()))?;
            }
        }
        Ok(())
    }
}

/// Keep only the *first* failure: it is the root cause surfaced by
/// `run()`; later failures are usually fallout.
fn record_first_error(inner: &Arc<Inner>, e: HicrError) {
    let mut first = inner.first_error.lock().unwrap();
    if first.is_none() {
        *first = Some(e);
    }
}

/// Engine-independent task spawn.
fn spawn_task(inner: &Arc<Inner>, label: String, body: TaskBody, parent: Option<Arc<TaskNode>>) {
    inner.outstanding.fetch_add(1, Ordering::AcqRel);
    let node = Arc::new(TaskNode {
        id: inner.next_task_id.fetch_add(1, Ordering::Relaxed),
        label,
        parent,
        sync: Mutex::new(TaskSync {
            pending_children: 0,
            waiting: false,
            ready_now: false,
            parked: None,
        }),
        cv: Condvar::new(),
    });
    match inner.engine {
        EngineKind::Suspending => {
            let engine = inner.suspending.as_ref().expect("suspending engine");
            let inner2 = Arc::clone(inner);
            let node2 = Arc::clone(&node);
            let body_cell = Mutex::new(Some(body));
            let unit = FnExecutionUnit::new(node.label.clone(), move |ctx| {
                let body = body_cell.lock().unwrap().take().expect("body runs once");
                let tctx = TaskCtx {
                    inner: &inner2,
                    node: &node2,
                    exec: Some(ctx),
                };
                body(&tctx);
            });
            match inner.cm.create_execution_state(unit as Arc<dyn ExecutionUnit>) {
                Ok(state) => {
                    debug_assert!(state.supports_suspension());
                    enqueue(engine, SuspendableTask { node, state });
                }
                Err(e) => {
                    // Keep bookkeeping sound and surface the rejection
                    // through run() — a panic here would kill a worker
                    // thread mid-task and hang the system.
                    record_first_error(
                        inner,
                        HicrError::InvalidState(format!(
                            "backend '{}' rejected task '{}': {e}",
                            inner.cm.backend_name(),
                            node.label
                        )),
                    );
                    finish_task(inner, &node);
                }
            }
        }
        EngineKind::Blocking => {
            // Submit to the system-wide scheduler; the dispatcher
            // materializes a processing unit when a slot frees up.
            let engine = inner.blocking.as_ref().expect("blocking engine");
            engine.queue.lock().unwrap().push_back((body, node));
            engine.queue_cv.notify_one();
        }
    }
}

/// The blocking-engine scheduler pump: pop a submitted task, acquire a
/// slot, and run it on a dedicated processing unit of the injected
/// compute manager (thread-per-task at *schedule* time for backends like
/// nosv; a fresh queue-worker thread for the threads backend).
fn blocking_dispatcher_loop(inner: Arc<Inner>) {
    let engine = inner.blocking.as_ref().expect("blocking engine");
    loop {
        let next = {
            let mut queue = engine.queue.lock().unwrap();
            loop {
                if let Some(t) = queue.pop_back() {
                    break Some(t);
                }
                if engine.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = engine.queue_cv.wait(queue).unwrap();
            }
        };
        let Some((body, node)) = next else {
            // Shutdown: join the executors of every finished task.
            let mut live = engine.live.lock().unwrap();
            for (pu, _state) in live.drain(..) {
                let _ = pu.terminate();
            }
            return;
        };
        let slot = engine.slots.acquire();
        // Garbage-collect processing units whose states finished.
        {
            let mut live = engine.live.lock().unwrap();
            live.retain(|(pu, state)| {
                if state.is_finished() {
                    let _ = pu.terminate();
                    false
                } else {
                    true
                }
            });
        }
        let inner2 = Arc::clone(&inner);
        let node2 = Arc::clone(&node);
        let body_cell = Mutex::new(Some(body));
        let unit = FnExecutionUnit::new(node.label.clone(), move |ctx| {
            let body = body_cell.lock().unwrap().take().expect("body runs once");
            let engine = inner2.blocking.as_ref().expect("blocking engine");
            set_task_slot(Some(slot));
            let t0 = inner2.trace.now_ns();
            let tctx = TaskCtx {
                inner: &inner2,
                node: &node2,
                exec: Some(ctx),
            };
            // Catch panics so bookkeeping always runs: a lost
            // finish_task would hang the whole system. The panic is not
            // swallowed — it surfaces as the run()'s error.
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                body(&tctx)
            }));
            if outcome.is_err() {
                record_first_error(
                    &inner2,
                    HicrError::InvalidState(format!("task '{}' panicked", node2.label)),
                );
            }
            inner2.trace.record(
                current_task_slot().unwrap_or(slot),
                EventKind::Run,
                &node2.label,
                t0,
            );
            if let Some(s) = current_task_slot() {
                engine.slots.release(s);
                set_task_slot(None);
            }
            finish_task(&inner2, &node2);
        });
        // Route through the abstract manager: state + processing unit.
        let started = inner
            .cm
            .create_execution_state(unit as Arc<dyn ExecutionUnit>)
            .and_then(|state| {
                let resource = ComputeResource {
                    id: ComputeResourceId(slot as u64),
                    kind: "cpu-core".into(),
                    os_index: slot as u32,
                    locality: 0,
                };
                let pu = inner.cm.create_processing_unit(&resource)?;
                pu.start(Arc::clone(&state))?;
                Ok((pu, state))
            });
        match started {
            Ok(pair) => engine.live.lock().unwrap().push(pair),
            Err(e) => {
                // The manager rejected the task (wrong unit format /
                // terminated unit). Record the first rejection so the
                // enclosing `run()` fails loudly — silently dropping work
                // would report wrong results with a clean exit — while
                // keeping the graph bookkeeping sound so `run()` returns.
                record_first_error(
                    &inner,
                    HicrError::InvalidState(format!(
                        "backend '{}' rejected task '{}': {e}",
                        inner.cm.backend_name(),
                        node.label
                    )),
                );
                engine.slots.release(slot);
                finish_task(&inner, &node);
            }
        }
    }
}

fn enqueue(engine: &SuspendingEngine, task: SuspendableTask) {
    engine.ready.lock().unwrap().push_back(task);
    engine.ready_cv.notify_one();
}

/// Common completion path: notify the parent and the system.
fn finish_task(inner: &Arc<Inner>, node: &Arc<TaskNode>) {
    inner.tasks_executed.fetch_add(1, Ordering::Relaxed);
    if let Some(parent) = &node.parent {
        let to_enqueue = {
            let mut sync = parent.sync.lock().unwrap();
            sync.pending_children -= 1;
            if sync.pending_children == 0 && sync.waiting {
                sync.waiting = false;
                match sync.parked.take() {
                    Some(task) => Some(task),
                    None => {
                        // Parent not parked yet: flag it ready
                        // (suspending) / wake it (blocking).
                        sync.ready_now = true;
                        None
                    }
                }
            } else {
                None
            }
        };
        parent.cv.notify_all();
        if let Some(task) = to_enqueue {
            let engine = inner.suspending.as_ref().expect("parked implies suspending");
            enqueue(engine, task);
        }
    }
    if inner.outstanding.fetch_sub(1, Ordering::AcqRel) == 1 {
        let _g = inner.done_mx.lock().unwrap();
        inner.done_cv.notify_all();
    }
}

/// The suspending-engine worker pull loop (paper §4.3 Tasking: worker
/// objects), driving opaque `dyn ExecutionState`s via `resume()`.
fn suspending_worker_loop(inner: Arc<Inner>, worker_id: usize) {
    let engine = inner.suspending.as_ref().expect("suspending engine");
    loop {
        // Pull the next ready task.
        let task = {
            let mut ready = engine.ready.lock().unwrap();
            loop {
                if let Some(t) = ready.pop_back() {
                    break Some(t);
                }
                if engine.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                ready = engine.ready_cv.wait(ready).unwrap();
            }
        };
        let Some(task) = task else { return };
        let t0 = inner.trace.now_ns();
        let status = match task.state.resume() {
            Ok(s) => s,
            Err(e) => {
                record_first_error(
                    &inner,
                    HicrError::InvalidState(format!(
                        "task '{}' could not be resumed: {e}",
                        task.node.label
                    )),
                );
                ExecStatus::Failed
            }
        };
        inner
            .trace
            .record(worker_id, EventKind::Run, &task.node.label, t0);
        match status {
            ExecStatus::Finished => {
                finish_task(&inner, &task.node);
            }
            ExecStatus::Failed => {
                // A failed state means the task body panicked (or the
                // backend broke mid-task): surface it, don't report a
                // clean run with missing work.
                record_first_error(
                    &inner,
                    HicrError::InvalidState(format!(
                        "task '{}' failed (panicked)",
                        task.node.label
                    )),
                );
                finish_task(&inner, &task.node);
            }
            ExecStatus::Suspended => {
                let mut sync = task.node.sync.lock().unwrap();
                if sync.ready_now {
                    // Children finished before we could park.
                    sync.ready_now = false;
                    drop(sync);
                    enqueue(engine, task);
                } else if sync.waiting && sync.pending_children > 0 {
                    // Park; child completion re-enqueues.
                    sync.parked = Some(task.clone());
                } else {
                    // Voluntary yield.
                    drop(sync);
                    enqueue(engine, task);
                }
            }
            other => {
                debug_assert!(false, "unexpected resume status {other:?}");
                finish_task(&inner, &task.node);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::coro::CoroComputeManager;
    use crate::backends::nosv::NosvComputeManager;
    use crate::backends::threads::ThreadsComputeManager;

    fn coro_cm() -> Arc<dyn ComputeManager> {
        Arc::new(CoroComputeManager::new())
    }

    fn nosv_cm() -> Arc<dyn ComputeManager> {
        Arc::new(NosvComputeManager::new())
    }

    fn threads_cm() -> Arc<dyn ComputeManager> {
        Arc::new(ThreadsComputeManager::new())
    }

    fn run_tree(cm: Arc<dyn ComputeManager>) -> u64 {
        // Three-level tree: root -> 3 children -> 2 grandchildren each.
        let sys = TaskSystem::new(cm, 4, false);
        let total = Arc::new(AtomicU64::new(0));
        let t = Arc::clone(&total);
        sys.run("root", move |ctx| {
            for _ in 0..3 {
                let t = Arc::clone(&t);
                ctx.spawn("child", move |cctx| {
                    for _ in 0..2 {
                        let t = Arc::clone(&t);
                        cctx.spawn("grandchild", move |_| {
                            t.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                    cctx.wait_children();
                    t.fetch_add(10, Ordering::SeqCst);
                });
            }
            ctx.wait_children();
            t.fetch_add(100, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(sys.tasks_executed(), 10);
        total.load(Ordering::SeqCst)
    }

    #[test]
    fn suspending_engine_tree_dependencies() {
        assert_eq!(run_tree(coro_cm()), 136);
    }

    #[test]
    fn blocking_engine_tree_dependencies() {
        assert_eq!(run_tree(nosv_cm()), 136);
    }

    #[test]
    fn threads_backend_tree_dependencies() {
        // Any run-to-completion manager works — not only nosv.
        assert_eq!(run_tree(threads_cm()), 136);
    }

    /// A compute manager that rejects every execution unit (stand-in for
    /// selecting a plugin that does not prescribe host closures).
    struct RejectingCompute;

    impl ComputeManager for RejectingCompute {
        fn create_processing_unit(
            &self,
            _resource: &ComputeResource,
        ) -> Result<Arc<dyn ProcessingUnit>> {
            Err(HicrError::Unsupported("no processing units".into()))
        }

        fn create_execution_state(
            &self,
            _unit: Arc<dyn ExecutionUnit>,
        ) -> Result<Arc<dyn ExecutionState>> {
            Err(HicrError::Unsupported("no host closures".into()))
        }

        fn backend_name(&self) -> &'static str {
            "rejecting"
        }
    }

    #[test]
    fn backend_rejection_surfaces_from_run() {
        // A backend that cannot execute the task must fail the run, not
        // silently report success with dropped work.
        let sys = TaskSystem::new(Arc::new(RejectingCompute), 2, false);
        let err = sys.run("r", |_| {}).unwrap_err();
        assert!(err.to_string().contains("rejected task"), "{err}");
        sys.shutdown().unwrap();
    }

    #[test]
    fn engine_negotiated_from_capability() {
        let sys = TaskSystem::new(coro_cm(), 1, false);
        assert!(sys.suspending());
        assert_eq!(sys.backend_name(), "coro");
        sys.shutdown().unwrap();
        let sys = TaskSystem::new(threads_cm(), 1, false);
        assert!(!sys.suspending());
        assert_eq!(sys.backend_name(), "threads");
        sys.shutdown().unwrap();
    }

    #[test]
    fn coro_small_fibonacci() {
        // fib(10) = 55 via the naive recursive task DAG.
        let sys = TaskSystem::new(coro_cm(), 4, false);
        let result = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&result);
        sys.run("fib", move |ctx| {
            let v = fib_task(ctx, 10);
            r.store(v, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(result.load(Ordering::SeqCst), 55);
    }

    /// The naive recursive Fibonacci as nested tasks (test-local copy of
    /// the app pattern).
    fn fib_task(ctx: &TaskCtx, n: u64) -> u64 {
        if n < 2 {
            return n;
        }
        let a = Arc::new(AtomicU64::new(0));
        let b = Arc::new(AtomicU64::new(0));
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        ctx.spawn("fib-l", move |c| {
            let v = fib_task(c, n - 1);
            a2.store(v, Ordering::SeqCst);
        });
        ctx.spawn("fib-r", move |c| {
            let v = fib_task(c, n - 2);
            b2.store(v, Ordering::SeqCst);
        });
        ctx.wait_children();
        a.load(Ordering::SeqCst) + b.load(Ordering::SeqCst)
    }

    #[test]
    fn nosv_small_fibonacci() {
        let sys = TaskSystem::new(nosv_cm(), 4, false);
        let result = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&result);
        sys.run("fib", move |ctx| {
            let v = fib_task(ctx, 9);
            r.store(v, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(result.load(Ordering::SeqCst), 34);
    }

    #[test]
    fn trace_collects_task_events() {
        let sys = TaskSystem::new(coro_cm(), 2, true);
        sys.run("traced", |ctx| {
            for _ in 0..4 {
                ctx.spawn("leaf", |_| {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                });
            }
            ctx.wait_children();
        })
        .unwrap();
        sys.shutdown().unwrap();
        let events = sys.trace().events();
        assert!(events.len() >= 5, "root + 4 leaves, got {}", events.len());
        assert!(events.iter().any(|e| e.label == "leaf"));
    }

    #[test]
    fn sequential_runs_reuse_system() {
        let sys = TaskSystem::new(coro_cm(), 2, false);
        for _ in 0..3 {
            sys.run("r", |ctx| {
                ctx.spawn("c", |_| {});
                ctx.wait_children();
            })
            .unwrap();
        }
        sys.shutdown().unwrap();
        assert_eq!(sys.tasks_executed(), 6);
    }

    #[test]
    fn deep_recursion_no_worker_starvation() {
        // A chain of depth 50 where every level waits on its child: far
        // deeper than the worker count — only user-level parking survives
        // this without deadlock.
        fn chain(ctx: &TaskCtx, depth: u32, hits: Arc<AtomicU64>) {
            if depth == 0 {
                hits.fetch_add(1, Ordering::SeqCst);
                return;
            }
            let h = Arc::clone(&hits);
            ctx.spawn("link", move |c| chain(c, depth - 1, h));
            ctx.wait_children();
        }
        let sys = TaskSystem::new(coro_cm(), 2, false);
        let hits = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hits);
        sys.run("chain", move |ctx| chain(ctx, 50, h)).unwrap();
        sys.shutdown().unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
