//! The task system: stateful tasks, work-stealing workers, and two
//! scheduling engines — selected by *capability negotiation* against the
//! injected compute manager, never by naming a concrete backend.
//!
//! # Scheduling architecture (DESIGN.md §5)
//!
//! Every worker owns a private ready deque: it pushes and pops at the
//! bottom (LIFO — depth-first execution with hot caches) while idle
//! workers steal from the top (FIFO — the oldest, coarsest task).
//! Victims are scanned in a topology-aware order: same-NUMA workers
//! first (per the `locality` of the compute resources assigned from an
//! optional [`crate::core::topology::Topology`]), remote domains last.
//! A single *injection lane* — the only globally locked structure —
//! carries external submissions ([`TaskSystem::submit`] / `run`) and is
//! demoted to an overflow path: the steady-state spawn→run→complete
//! cycle of a task spawned *by* a task touches only per-worker state
//! (asserted by the lock-count instrument, [`TaskSystem::sched_stats`]).
//! Idle workers escalate through [`crate::util::backoff::Backoff`]
//! (spin → yield) and then park on a per-worker parker; producers wake
//! one parked worker per push, and waking costs one atomic load when
//! nobody is parked.
//!
//! # Engines
//!
//! `TaskSystem::new` accepts any [`ComputeManager`] trait object:
//!
//! - If the manager's execution states support cooperative suspension
//!   (`supports_suspension()`, e.g. the fiber-class `coro` plugin), tasks
//!   run on the **parking engine**: workers drive states with
//!   [`ExecutionState::resume`], and a task waiting on children parks
//!   *without* occupying its worker. A parked task's re-enqueue (and any
//!   fresh task) may be stolen and resumed by a *different* worker — the
//!   coro substrate explicitly supports cross-thread resume.
//! - Otherwise (run-to-completion states, e.g. the `threads` or `nosv`
//!   plugins) tasks run on the **blocking engine**: each worker executes
//!   its tasks through a processing unit of the injected manager, reusing
//!   one unit while tasks run to completion; a task that blocks in
//!   [`TaskCtx::wait_children`] releases its worker (the unit hosting the
//!   blocked task is retired to a zombie list and reclaimed when it
//!   finishes), so deep DAGs cannot starve the scheduler.
//!
//! # Task graphs
//!
//! Beyond the parent/child tree (`spawn` + `wait_children`), tasks form
//! explicit DAGs: [`TaskCtx::spawn_after`] gates a task on the completion
//! of previously spawned tasks (by [`TaskHandle`]), and
//! [`TaskCtx::spawn_dataflow`] expresses producer/consumer edges keyed by
//! `u64` *data keys* (the same id space the dataobject frontend uses for
//! its objects, so a task can be gated on the data it consumes).
//!
//! The paper's Test Case 3/4 engine comparison (Boost fibers vs nOS-V
//! thread-per-task) remains a pure backend swap: the same application
//! body runs under `--compute coro` or `--compute nosv`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar};

use crate::core::compute::{
    ComputeManager, ExecStatus, ExecutionState, ExecutionUnit, FnExecutionUnit,
    ProcessingUnit,
};
use crate::core::error::{HicrError, Result};
use crate::core::ids::ComputeResourceId;
use crate::core::topology::{ComputeResource, Topology};
use crate::frontends::tasking::deque::{Injector, Parker, SchedCounters, WorkDeque};
use crate::frontends::tasking::trace::{EventKind, Trace};
use crate::util::backoff::Backoff;
use crate::util::witness::{classes, Lock};

/// Which scheduling engine drives the tasks — derived from the compute
/// manager's capabilities, not chosen by the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EngineKind {
    /// Suspendable states: workers drive `resume()`, waiting tasks park.
    Suspending,
    /// Run-to-completion states: per-worker processing units, blocking
    /// waits release the worker.
    Blocking,
}

/// A task body: runs once, may spawn children and wait for them.
pub type TaskBody = Box<dyn FnOnce(&TaskCtx) + Send + 'static>;

/// How ready tasks are distributed across workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Per-worker deques with topology-aware stealing (the default).
    WorkStealing,
    /// Every task goes through the single global injection queue and
    /// stealing is disabled — the seed scheduler's contention pattern,
    /// kept as the *before* side of the fig9/sched_scaling ablations.
    GlobalQueue,
}

/// Scheduler construction options (see [`TaskSystem::with_config`]).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Ready-task distribution policy.
    pub policy: SchedPolicy,
    /// Hardware topology used to assign one compute resource per worker
    /// (round-robin over the NUMA domains' CPU resources): its `locality`
    /// drives the steal order and its `os_index` the optional pinning.
    /// `None` synthesizes one resource per worker on locality 0.
    pub topology: Option<Topology>,
    /// Pin scheduler workers (and, through the compute manager's
    /// processing units, task executors) to their resource's core.
    /// Best-effort; a no-op without the `affinity` feature.
    pub pin_workers: bool,
}

impl Default for SchedConfig {
    fn default() -> Self {
        Self {
            policy: SchedPolicy::WorkStealing,
            topology: None,
            pin_workers: true,
        }
    }
}

/// Snapshot of the scheduler's counters — the lock-count instrument the
/// acceptance tests (and the sched_scaling bench) read.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedStats {
    /// Pushes onto worker-local deques (steady-state spawn path).
    pub local_pushes: u64,
    /// Pushes onto the global injection lane.
    pub injection_pushes: u64,
    /// Mutex acquisitions of the injection lane — the only global
    /// scheduler lock. Steady-state task-to-task spawning must not move
    /// this counter.
    pub injection_locks: u64,
    /// Successful steals.
    pub steals: u64,
    /// Victim-scan rounds that found nothing.
    pub steal_failures: u64,
    /// Worker park events.
    pub parks: u64,
    /// Producer-side wakes of parked workers.
    pub wakes: u64,
    /// Steal RPCs issued to remote instances (distributed stealing;
    /// always 0 for a plain local [`TaskSystem`] — filled by
    /// [`super::steal::StealPool::sched_stats`]).
    pub remote_steal_attempts: u64,
    /// Steal RPCs that returned at least one task.
    pub remote_steals: u64,
    /// Descriptor tasks stolen *into* this instance.
    pub tasks_migrated_in: u64,
    /// Descriptor tasks stolen *out of* this instance's remote-ready
    /// lane by thieves.
    pub tasks_migrated_out: u64,
    /// Argument bytes this instance parked for lazy transfer: payloads a
    /// steal response deferred, pulled by the thief only at dispatch.
    pub lazy_payload_bytes: u64,
    /// Descriptor tasks re-enqueued after the instance holding them
    /// crashed (crash-ledger replays plus payload-lost re-spawns from
    /// retained args — DESIGN.md §9).
    pub tasks_recovered: u64,
    /// Completions discarded as zombies: results for unknown or
    /// already-completed task ids, surfacing when a task re-executed
    /// after a crash *and* its original executor's result still arrived.
    pub completions_discarded: u64,
}

/// Dependency/lifecycle bookkeeping shared by both engines.
struct TaskSync {
    pending_children: usize,
    waiting: bool,
    /// Set when a waiting parent became ready before it finished parking.
    ready_now: bool,
    /// Parked suspendable task awaiting child completion.
    parked: Option<SuspendableTask>,
}

/// Completion broadcast state for `spawn_after` edges.
struct DepState {
    finished: bool,
    /// Dep-gated tasks waiting on this node's completion.
    waiters: Vec<Arc<Pending>>,
}

struct TaskNode {
    #[allow(dead_code)]
    id: u64,
    label: String,
    parent: Option<Arc<TaskNode>>,
    sync: Lock<TaskSync>,
    /// Blocking engine: parents block here awaiting children.
    cv: Condvar,
    /// Completion broadcast for `spawn_after` dependents.
    dep: Lock<DepState>,
    /// Worker this task last executed on: the push target for its spawns
    /// (kept fresh across steals/resumes by the executing worker).
    home: AtomicUsize,
    /// Data keys marked produced when this task completes.
    produces: Vec<u64>,
    /// Blocking engine: one-shot flag — the first `wait_children` releases
    /// the worker; later waits by the same (resumed) task must not.
    worker_released: AtomicBool,
}

/// A dep-gated task that has not become ready yet. `remaining` starts at
/// 1 (a registration sentinel released after all edges are wired), so a
/// task whose dependencies all finished mid-registration is enqueued
/// exactly once.
struct Pending {
    remaining: AtomicUsize,
    slot: Lock<Option<(TaskBody, Arc<TaskNode>)>>,
}

/// A task bound to a suspendable execution state (parking engine).
#[derive(Clone)]
struct SuspendableTask {
    node: Arc<TaskNode>,
    state: Arc<dyn ExecutionState>,
}

/// A ready unit of work in a deque or the injection lane.
enum Runnable {
    /// Not yet started: the execution state is created at pop time.
    Fresh(TaskBody, Arc<TaskNode>),
    /// A suspended task re-enqueued for resumption (parking engine).
    Resume(SuspendableTask),
}

/// Completion handle for a spawned task: the dependency currency of
/// [`TaskCtx::spawn_after`]. Cloneable and cheap; valid only within the
/// [`TaskSystem`] that spawned it.
#[derive(Clone)]
pub struct TaskHandle {
    node: Arc<TaskNode>,
}

impl TaskHandle {
    /// True once the task has run to completion (its dependents have been
    /// released).
    pub fn is_finished(&self) -> bool {
        self.node.dep.lock().finished
    }
}

/// Producer/consumer state of one data key.
enum KeyState {
    /// The key's producer finished (or `mark_produced` was called).
    Produced,
    /// Consumers gated on the key.
    Waiting(Vec<Arc<Pending>>),
}

/// One scheduler worker's shared state.
struct Worker {
    deque: WorkDeque<Runnable>,
    parker: Parker,
    parked: AtomicBool,
    /// Victim scan order: same-locality workers first, ring-rotated so
    /// thieves do not all converge on worker 0.
    steal_order: Vec<usize>,
    /// The compute resource this worker schedules onto (drives pinning
    /// and the locality-aware steal order).
    resource: ComputeResource,
}

struct Sched {
    workers: Vec<Worker>,
    injector: Injector<Runnable>,
    /// Number of currently parked workers (wake fast-path probe).
    idle: AtomicUsize,
    shutdown: AtomicBool,
    policy: SchedPolicy,
    counters: SchedCounters,
    pin_workers: bool,
    handles: Lock<Vec<std::thread::JoinHandle<()>>>,
}

struct Inner {
    cm: Arc<dyn ComputeManager>,
    engine: EngineKind,
    trace: Arc<Trace>,
    next_task_id: AtomicU64,
    outstanding: AtomicUsize,
    done_mx: Lock<()>,
    done_cv: Condvar,
    tasks_executed: AtomicU64,
    /// First task the backend rejected (wrong unit format, terminated
    /// unit) or that panicked: surfaced as the error of the enclosing
    /// `run()` so a mis-selected backend fails loudly instead of
    /// reporting wrong results.
    first_error: Lock<Option<HicrError>>,
    sched: Sched,
    keys: Lock<HashMap<u64, KeyState>>,
}

/// One-shot gate the blocking engine's worker waits on per started task:
/// fired with `Blocked` by the first `wait_children` (the worker moves on
/// and retires the task's processing unit) or with `Done` when the body
/// returns. Only the first fire counts.
struct StartGate {
    state: Lock<Option<GateEvent>>,
    cv: Condvar,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum GateEvent {
    Blocked,
    Done,
}

impl StartGate {
    fn new() -> Self {
        Self {
            state: Lock::new(&classes::TASKING_START_GATE, None),
            cv: Condvar::new(),
        }
    }

    fn fire(&self, ev: GateEvent) {
        let mut s = self.state.lock();
        if s.is_none() {
            *s = Some(ev);
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> GateEvent {
        let mut s = self.state.lock();
        loop {
            if let Some(ev) = *s {
                return ev;
            }
            s = s.wait(&self.cv);
        }
    }
}

/// Handle task bodies use to spawn children and synchronize (the only
/// API the Fibonacci/Jacobi apps see — engine-independent).
pub struct TaskCtx<'a> {
    inner: &'a Arc<Inner>,
    node: &'a Arc<TaskNode>,
    exec: Option<&'a crate::core::compute::ExecCtx<'a>>,
    /// Blocking engine: the gate releasing this task's worker.
    gate: Option<&'a StartGate>,
}

impl<'a> TaskCtx<'a> {
    /// Spawn a child task onto this worker's deque. The child may itself
    /// spawn and wait; the returned [`TaskHandle`] can gate later
    /// [`TaskCtx::spawn_after`] spawns.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use std::sync::atomic::{AtomicU64, Ordering};
    /// use hicr::backends::threads::ThreadsComputeManager;
    /// use hicr::frontends::tasking::TaskSystem;
    ///
    /// let sys = TaskSystem::new(Arc::new(ThreadsComputeManager::new()), 2, false);
    /// let total = Arc::new(AtomicU64::new(0));
    /// let t = Arc::clone(&total);
    /// sys.run("root", move |ctx| {
    ///     for _ in 0..4 {
    ///         let t = Arc::clone(&t);
    ///         ctx.spawn("leaf", move |_| {
    ///             t.fetch_add(1, Ordering::Relaxed);
    ///         });
    ///     }
    ///     ctx.wait_children();
    /// })
    /// .unwrap();
    /// sys.shutdown().unwrap();
    /// assert_eq!(total.load(Ordering::Relaxed), 4);
    /// ```
    pub fn spawn(
        &self,
        label: impl Into<String>,
        body: impl FnOnce(&TaskCtx) + Send + 'static,
    ) -> TaskHandle {
        let node = create_node(self.inner, label.into(), Some(Arc::clone(self.node)), Vec::new());
        let handle = TaskHandle {
            node: Arc::clone(&node),
        };
        schedule(self.inner, self.home(), Runnable::Fresh(Box::new(body), node));
        handle
    }

    /// Spawn a task that becomes ready only after every task in `deps`
    /// has completed — an explicit DAG edge, independent of the
    /// parent/child tree (the child still counts for
    /// [`TaskCtx::wait_children`]).
    ///
    /// Handles from a *different* `TaskSystem` are a logic error: the
    /// dependency would release into the wrong scheduler.
    ///
    /// ```
    /// use std::sync::Arc;
    /// use std::sync::atomic::{AtomicU64, Ordering};
    /// use hicr::backends::threads::ThreadsComputeManager;
    /// use hicr::frontends::tasking::TaskSystem;
    ///
    /// let sys = TaskSystem::new(Arc::new(ThreadsComputeManager::new()), 2, false);
    /// let value = Arc::new(AtomicU64::new(0));
    /// let v = Arc::clone(&value);
    /// sys.run("root", move |ctx| {
    ///     let v1 = Arc::clone(&v);
    ///     let a = ctx.spawn("producer-a", move |_| {
    ///         v1.fetch_add(2, Ordering::SeqCst);
    ///     });
    ///     let v2 = Arc::clone(&v);
    ///     let b = ctx.spawn("producer-b", move |_| {
    ///         v2.fetch_add(3, Ordering::SeqCst);
    ///     });
    ///     let v3 = Arc::clone(&v);
    ///     // Runs only after both producers: observes 2 + 3 = 5.
    ///     ctx.spawn_after(&[a, b], "consumer", move |_| {
    ///         assert_eq!(v3.load(Ordering::SeqCst), 5);
    ///         v3.fetch_add(10, Ordering::SeqCst);
    ///     });
    ///     ctx.wait_children();
    /// })
    /// .unwrap();
    /// sys.shutdown().unwrap();
    /// assert_eq!(value.load(Ordering::SeqCst), 15);
    /// ```
    pub fn spawn_after(
        &self,
        deps: &[TaskHandle],
        label: impl Into<String>,
        body: impl FnOnce(&TaskCtx) + Send + 'static,
    ) -> TaskHandle {
        self.spawn_gated(deps, &[], &[], label.into(), Box::new(body))
    }

    /// Spawn a task gated on data keys: it becomes ready once every key
    /// in `consumes` has been produced (by a completed producer task or
    /// [`TaskSystem::mark_produced`]), and marks every key in `produces`
    /// produced when it completes. Keys are produce-once; they share the
    /// dataobject frontend's `u64` id space so a pipeline stage can be
    /// gated on the object it consumes.
    pub fn spawn_dataflow(
        &self,
        label: impl Into<String>,
        consumes: &[u64],
        produces: &[u64],
        body: impl FnOnce(&TaskCtx) + Send + 'static,
    ) -> TaskHandle {
        self.spawn_gated(&[], consumes, produces, label.into(), Box::new(body))
    }

    /// Common gated-spawn path for handle and data-key edges.
    fn spawn_gated(
        &self,
        deps: &[TaskHandle],
        consumes: &[u64],
        produces: &[u64],
        label: String,
        body: TaskBody,
    ) -> TaskHandle {
        let node = create_node(
            self.inner,
            label,
            Some(Arc::clone(self.node)),
            produces.to_vec(),
        );
        let handle = TaskHandle {
            node: Arc::clone(&node),
        };
        let pending = Arc::new(Pending {
            // The +1 sentinel is released after registration, so deps
            // finishing concurrently cannot double-enqueue.
            remaining: AtomicUsize::new(1),
            slot: Lock::new(&classes::TASKING_PENDING_SLOT, Some((body, node))),
        });
        for dep in deps {
            let mut d = dep.node.dep.lock();
            if !d.finished {
                pending.remaining.fetch_add(1, Ordering::AcqRel);
                d.waiters.push(Arc::clone(&pending));
            }
        }
        if !consumes.is_empty() {
            let mut keys = self.inner.keys.lock();
            for &key in consumes {
                match keys.entry(key).or_insert_with(|| KeyState::Waiting(Vec::new())) {
                    KeyState::Produced => {}
                    KeyState::Waiting(v) => {
                        pending.remaining.fetch_add(1, Ordering::AcqRel);
                        v.push(Arc::clone(&pending));
                    }
                }
            }
        }
        release_pending(self.inner, &pending, self.home());
        handle
    }

    /// Wait until every child spawned by this task has finished.
    pub fn wait_children(&self) {
        match self.inner.engine {
            EngineKind::Suspending => {
                // Park the state; child completion re-enqueues us.
                loop {
                    {
                        let mut sync = self.node.sync.lock();
                        if sync.pending_children == 0 {
                            return;
                        }
                        sync.waiting = true;
                    }
                    self.exec
                        .expect("suspending task without exec ctx")
                        .suspend();
                }
            }
            EngineKind::Blocking => {
                {
                    let sync = self.node.sync.lock();
                    if sync.pending_children == 0 {
                        return;
                    }
                }
                // Release our worker (one-shot) so it schedules other
                // tasks — including our children — then block this
                // kernel thread until they finish.
                if !self.node.worker_released.swap(true, Ordering::AcqRel) {
                    if let Some(gate) = self.gate {
                        gate.fire(GateEvent::Blocked);
                    }
                }
                let mut sync = self.node.sync.lock();
                while sync.pending_children > 0 {
                    sync = sync.wait(&self.node.cv);
                }
            }
        }
    }

    /// The worker this task last executed on (its spawn push target).
    fn home(&self) -> Option<usize> {
        // relaxed-ok: worker-affinity hint; a stale value only degrades victim choice
        let h = self.node.home.load(Ordering::Relaxed);
        (h != usize::MAX).then_some(h)
    }
}

/// The task system facade.
///
/// ```
/// use std::sync::Arc;
/// use hicr::backends::threads::ThreadsComputeManager;
/// use hicr::frontends::tasking::TaskSystem;
///
/// // Any compute manager works; the engine is negotiated from its
/// // suspension capability (threads → blocking engine).
/// let sys = TaskSystem::new(Arc::new(ThreadsComputeManager::new()), 2, false);
/// assert_eq!(sys.n_workers(), 2);
/// sys.run("hello", |_ctx| {}).unwrap();
/// sys.shutdown().unwrap();
/// ```
pub struct TaskSystem {
    inner: Arc<Inner>,
    n_workers: usize,
}

impl TaskSystem {
    /// Create a system with `n_workers` work-stealing workers executing
    /// through `cm`. Any compute manager whose execution units are host
    /// closures works; the scheduling engine is negotiated from the
    /// manager's suspension capability. Equivalent to
    /// [`TaskSystem::with_config`] with the default [`SchedConfig`].
    pub fn new(
        cm: Arc<dyn ComputeManager>,
        n_workers: usize,
        trace_enabled: bool,
    ) -> Arc<TaskSystem> {
        Self::with_config(cm, n_workers, trace_enabled, SchedConfig::default())
    }

    /// Create a system with explicit scheduler options: the distribution
    /// policy (work-stealing vs the global-queue ablation baseline) and
    /// an optional hardware topology assigning workers to compute
    /// resources (NUMA-aware steal order + pinning).
    pub fn with_config(
        cm: Arc<dyn ComputeManager>,
        n_workers: usize,
        trace_enabled: bool,
        config: SchedConfig,
    ) -> Arc<TaskSystem> {
        assert!(n_workers > 0, "need at least one worker");
        let engine = if cm.supports_suspension() {
            EngineKind::Suspending
        } else {
            EngineKind::Blocking
        };
        let trace = Arc::new(Trace::new(trace_enabled));
        // Assign one compute resource per worker: round-robin over the
        // topology's CPU resources, synthesized when none are available.
        let cpu: Vec<ComputeResource> = config
            .topology
            .as_ref()
            .map(|t| t.cpu_resources().cloned().collect())
            .unwrap_or_default();
        let resources: Vec<ComputeResource> = (0..n_workers)
            .map(|w| {
                cpu.get(w % cpu.len().max(1)).cloned().unwrap_or_else(|| {
                    ComputeResource {
                        id: ComputeResourceId(w as u64),
                        kind: "cpu-core".into(),
                        os_index: w as u32,
                        locality: 0,
                    }
                })
            })
            .collect();
        let localities: Vec<u32> = resources.iter().map(|r| r.locality).collect();
        let workers: Vec<Worker> = resources
            .into_iter()
            .enumerate()
            .map(|(w, resource)| Worker {
                deque: WorkDeque::new(),
                parker: Parker::new(),
                parked: AtomicBool::new(false),
                steal_order: steal_order(&localities, w),
                resource,
            })
            .collect();
        let inner = Arc::new(Inner {
            cm,
            engine,
            trace,
            next_task_id: AtomicU64::new(1),
            outstanding: AtomicUsize::new(0),
            done_mx: Lock::new(&classes::TASKING_DONE, ()),
            done_cv: Condvar::new(),
            tasks_executed: AtomicU64::new(0),
            first_error: Lock::new(&classes::TASKING_FIRST_ERROR, None),
            sched: Sched {
                workers,
                injector: Injector::new(),
                idle: AtomicUsize::new(0),
                shutdown: AtomicBool::new(false),
                policy: config.policy,
                counters: SchedCounters::default(),
                pin_workers: config.pin_workers,
                handles: Lock::new(&classes::TASKING_HANDLES, Vec::new()),
            },
            keys: Lock::new(&classes::TASKING_KEYS, HashMap::new()),
        });
        {
            let mut handles = inner.sched.handles.lock();
            for w in 0..n_workers {
                let inner2 = Arc::clone(&inner);
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("hicr-task-worker-{w}"))
                        .spawn(move || match inner2.engine {
                            EngineKind::Suspending => suspending_worker_loop(inner2, w),
                            EngineKind::Blocking => blocking_worker_loop(inner2, w),
                        })
                        .expect("spawn task worker"),
                );
            }
        }
        Arc::new(TaskSystem { inner, n_workers })
    }

    /// The backend executing the tasks.
    pub fn backend_name(&self) -> &'static str {
        self.inner.cm.backend_name()
    }

    /// True when the parking (user-level suspension) engine is active.
    pub fn suspending(&self) -> bool {
        self.inner.engine == EngineKind::Suspending
    }

    /// The execution tracer (records per-worker run intervals when the
    /// system was built with tracing enabled).
    pub fn trace(&self) -> Arc<Trace> {
        Arc::clone(&self.inner.trace)
    }

    /// Number of scheduler workers.
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Tasks executed to completion so far.
    pub fn tasks_executed(&self) -> u64 {
        // relaxed-ok: telemetry counter; no data is published through this atomic
        self.inner.tasks_executed.load(Ordering::Relaxed)
    }

    /// Snapshot of the scheduler counters (the lock-count instrument).
    pub fn sched_stats(&self) -> SchedStats {
        let c = &self.inner.sched.counters;
        SchedStats {
            // relaxed-ok: telemetry counter; no data is published through this atomic
            local_pushes: c.local_pushes.load(Ordering::Relaxed),
            injection_pushes: c.injection_pushes.load(Ordering::Relaxed),
            injection_locks: self.inner.sched.injector.lock_count(),
            steals: c.steals.load(Ordering::Relaxed),
            // relaxed-ok: telemetry counter; no data is published through this atomic
            steal_failures: c.steal_failures.load(Ordering::Relaxed),
            parks: c.parks.load(Ordering::Relaxed),
            wakes: c.wakes.load(Ordering::Relaxed),
            // Remote-steal telemetry lives in the instance-level
            // StealPool, not in the (purely local) scheduler core.
            ..SchedStats::default()
        }
    }

    /// Ready tasks currently queued (injection lane + every worker
    /// deque). The saturation signal the taskfarm app's distributed
    /// spill path keys on.
    pub fn ready_backlog(&self) -> usize {
        let s = &self.inner.sched;
        s.injector.len() + s.workers.iter().map(|w| w.deque.len()).sum::<usize>()
    }

    /// Submit a root task through the injection lane without waiting.
    /// Use [`TaskSystem::wait_idle`] to block until the graph quiesces.
    pub fn submit(
        &self,
        label: impl Into<String>,
        body: impl FnOnce(&TaskCtx) + Send + 'static,
    ) -> TaskHandle {
        let node = create_node(&self.inner, label.into(), None, Vec::new());
        let handle = TaskHandle {
            node: Arc::clone(&node),
        };
        schedule(&self.inner, None, Runnable::Fresh(Box::new(body), node));
        handle
    }

    /// Mark a data key produced from outside the task graph (e.g. when a
    /// dataobject arrives over a channel), releasing every
    /// [`TaskCtx::spawn_dataflow`] consumer gated on it.
    pub fn mark_produced(&self, key: u64) {
        produce_key(&self.inner, key, None);
    }

    /// Block until every outstanding task (including dep-gated ones) has
    /// completed; surfaces the first backend rejection or task panic.
    pub fn wait_idle(&self) -> Result<()> {
        let mut guard = self.inner.done_mx.lock();
        while self.inner.outstanding.load(Ordering::Acquire) != 0 {
            guard = guard.wait(&self.inner.done_cv);
        }
        drop(guard);
        if let Some(e) = self.inner.first_error.lock().take() {
            return Err(e);
        }
        Ok(())
    }

    /// Spawn a root task and block until the whole task graph quiesces.
    /// Fails if the backend rejected any task (e.g. a compute plugin
    /// that does not prescribe host-closure execution units) or any task
    /// panicked.
    pub fn run(
        &self,
        label: impl Into<String>,
        body: impl FnOnce(&TaskCtx) + Send + 'static,
    ) -> Result<()> {
        self.submit(label, body);
        self.wait_idle()
    }

    /// Stop and join the workers. Call after the last `run`; idempotent,
    /// and also invoked by `Drop`. Parked workers are woken (even when a
    /// task error was recorded) so shutdown can never strand a worker on
    /// an empty deque.
    pub fn shutdown(&self) -> Result<()> {
        let sched = &self.inner.sched;
        sched.shutdown.store(true, Ordering::SeqCst);
        for w in &sched.workers {
            w.parker.unpark();
        }
        let mut handles = sched.handles.lock();
        for h in handles.drain(..) {
            h.join()
                .map_err(|_| HicrError::InvalidState("task worker panicked".into()))?;
        }
        Ok(())
    }
}

impl Drop for TaskSystem {
    fn drop(&mut self) {
        // Last-resort cleanup: joins workers even when the caller forgot
        // (or an error path skipped) `shutdown()`.
        let _ = self.shutdown();
    }
}

/// Victim scan order for worker `w`: same-locality workers first, each
/// group in ring order starting after `w` (so thieves spread instead of
/// converging on worker 0).
fn steal_order(localities: &[u32], w: usize) -> Vec<usize> {
    let n = localities.len();
    let mut order: Vec<usize> = (0..n).filter(|&v| v != w).collect();
    order.sort_by_key(|&v| (localities[v] != localities[w], (v + n - w) % n));
    order
}

/// Allocate a task node and account it as outstanding (dep-gated tasks
/// count from creation so `run`/`wait_idle` cannot quiesce early).
fn create_node(
    inner: &Arc<Inner>,
    label: String,
    parent: Option<Arc<TaskNode>>,
    produces: Vec<u64>,
) -> Arc<TaskNode> {
    if let Some(p) = &parent {
        p.sync.lock().pending_children += 1;
    }
    inner.outstanding.fetch_add(1, Ordering::AcqRel);
    Arc::new(TaskNode {
        // relaxed-ok: unique-id allocation; only atomicity matters, no payload is published
        id: inner.next_task_id.fetch_add(1, Ordering::Relaxed),
        label,
        parent,
        sync: Lock::new(&classes::TASKING_NODE_SYNC, TaskSync {
            pending_children: 0,
            waiting: false,
            ready_now: false,
            parked: None,
        }),
        cv: Condvar::new(),
        dep: Lock::new(&classes::TASKING_NODE_DEP, DepState {
            finished: false,
            waiters: Vec::new(),
        }),
        home: AtomicUsize::new(usize::MAX),
        produces,
        worker_released: AtomicBool::new(false),
    })
}

/// Push a ready runnable: onto `worker`'s deque under work-stealing (the
/// steady-state, global-lock-free path), or the injection lane otherwise;
/// then wake one parked worker if any.
fn schedule(inner: &Arc<Inner>, worker: Option<usize>, runnable: Runnable) {
    let sched = &inner.sched;
    match (sched.policy, worker) {
        (SchedPolicy::WorkStealing, Some(w)) => {
            sched.workers[w].deque.push_bottom(runnable);
            // relaxed-ok: telemetry counter; no data is published through this atomic
            sched.counters.local_pushes.fetch_add(1, Ordering::Relaxed);
        }
        _ => {
            sched.injector.push(runnable);
            sched
                .counters
                .injection_pushes
                // relaxed-ok: telemetry counter; no data is published through this atomic
                .fetch_add(1, Ordering::Relaxed);
        }
    }
    wake_one(sched);
}

/// Wake one parked worker; costs a single atomic load when nobody is
/// parked (the steady-state case). The waker *claims* the target's
/// `parked` flag (CAS true→false) so a burst of pushes fans out across
/// distinct parked workers instead of repeatedly waking the first one
/// before it has been scheduled to clear its own flag.
fn wake_one(sched: &Sched) {
    if sched.idle.load(Ordering::SeqCst) == 0 {
        return;
    }
    for w in &sched.workers {
        if w.parked
            .compare_exchange(true, false, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            // relaxed-ok: telemetry counter; no data is published through this atomic
            sched.counters.wakes.fetch_add(1, Ordering::Relaxed);
            w.parker.unpark();
            return;
        }
    }
}

/// Release one edge of a dep-gated task; the release dropping `remaining`
/// to zero schedules it (near the releasing worker when known).
fn release_pending(inner: &Arc<Inner>, pending: &Arc<Pending>, worker: Option<usize>) {
    if pending.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
        if let Some((body, node)) = pending.slot.lock().take() {
            schedule(inner, worker, Runnable::Fresh(body, node));
        }
    }
}

/// Mark `key` produced, releasing gated consumers. Produce-once: a second
/// production is a no-op.
fn produce_key(inner: &Arc<Inner>, key: u64, worker: Option<usize>) {
    let waiters = {
        let mut keys = inner.keys.lock();
        match keys.insert(key, KeyState::Produced) {
            Some(KeyState::Waiting(v)) => v,
            _ => Vec::new(),
        }
    };
    for p in &waiters {
        release_pending(inner, p, worker);
    }
}

/// Keep only the *first* failure: it is the root cause surfaced by
/// `run()`; later failures are usually fallout.
fn record_first_error(inner: &Arc<Inner>, e: HicrError) {
    let mut first = inner.first_error.lock();
    if first.is_none() {
        *first = Some(e);
    }
}

fn record_rejection(inner: &Arc<Inner>, node: &TaskNode, e: &HicrError) {
    record_first_error(
        inner,
        HicrError::InvalidState(format!(
            "backend '{}' rejected task '{}': {e}",
            inner.cm.backend_name(),
            node.label
        )),
    );
}

/// Common completion path: notify the parent, release dependents and
/// produced keys, and signal quiescence. `worker` is the completing
/// worker — released work is scheduled near it.
fn finish_task(inner: &Arc<Inner>, node: &Arc<TaskNode>, worker: Option<usize>) {
    // relaxed-ok: telemetry counter; no data is published through this atomic
    inner.tasks_executed.fetch_add(1, Ordering::Relaxed);
    if let Some(parent) = &node.parent {
        let to_enqueue = {
            let mut sync = parent.sync.lock();
            sync.pending_children -= 1;
            if sync.pending_children == 0 && sync.waiting {
                sync.waiting = false;
                match sync.parked.take() {
                    Some(task) => Some(task),
                    None => {
                        // Parent not parked yet: flag it ready
                        // (suspending) / wake it (blocking).
                        sync.ready_now = true;
                        None
                    }
                }
            } else {
                None
            }
        };
        parent.cv.notify_all();
        if let Some(task) = to_enqueue {
            schedule(inner, worker, Runnable::Resume(task));
        }
    }
    let waiters = {
        let mut dep = node.dep.lock();
        dep.finished = true;
        std::mem::take(&mut dep.waiters)
    };
    for p in &waiters {
        release_pending(inner, p, worker);
    }
    for &key in &node.produces {
        produce_key(inner, key, worker);
    }
    if inner.outstanding.fetch_sub(1, Ordering::AcqRel) == 1 {
        let _g = inner.done_mx.lock();
        inner.done_cv.notify_all();
    }
}

/// Pull the next runnable for worker `w`: own deque (LIFO) → injection
/// lane → steal round (topology order) → backoff, then park. Returns
/// `None` on shutdown with all visible work drained. `on_idle` runs once
/// per park cycle, before parking (the blocking engine reaps its retired
/// processing units there, so an idle system does not hold finished
/// executors until the next task arrives).
fn next_runnable(
    inner: &Arc<Inner>,
    w: usize,
    mut on_idle: impl FnMut(),
) -> Option<Runnable> {
    let sched = &inner.sched;
    let me = &sched.workers[w];
    let mut backoff = Backoff::new();
    loop {
        if let Some(r) = me.deque.pop_bottom() {
            return Some(r);
        }
        if let Some(r) = sched.injector.pop() {
            return Some(r);
        }
        if sched.policy == SchedPolicy::WorkStealing {
            let mut stolen = None;
            for &v in &me.steal_order {
                if let Some(r) = sched.workers[v].deque.steal_top() {
                    stolen = Some(r);
                    break;
                }
            }
            match stolen {
                Some(r) => {
                    // relaxed-ok: telemetry counter; no data is published through this atomic
                    sched.counters.steals.fetch_add(1, Ordering::Relaxed);
                    return Some(r);
                }
                None => {
                    sched
                        .counters
                        .steal_failures
                        // relaxed-ok: telemetry counter; no data is published through this atomic
                        .fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        if sched.shutdown.load(Ordering::SeqCst) {
            return None;
        }
        if backoff.is_sleeping() {
            // Park until a producer wakes us. The pre-park re-check (with
            // `parked` already published) closes the lost-wakeup window:
            // either the producer sees us parked, or we see its push.
            // The backoff is deliberately NOT reset afterwards: a
            // timeout wake re-scans the queues once and parks again
            // immediately, so a long-idle worker costs one scan per park
            // interval instead of re-running the whole spin/yield
            // escalation
            on_idle();
            // relaxed-ok: telemetry counter; no data is published through this atomic
            sched.counters.parks.fetch_add(1, Ordering::Relaxed);
            me.parked.store(true, Ordering::SeqCst);
            sched.idle.fetch_add(1, Ordering::SeqCst);
            // The worker scan covers our own deque too.
            let work_visible = sched.injector.len() > 0
                || sched.workers.iter().any(|wk| wk.deque.len() > 0)
                || sched.shutdown.load(Ordering::SeqCst);
            if !work_visible {
                me.parker.park();
            }
            me.parked.store(false, Ordering::SeqCst);
            sched.idle.fetch_sub(1, Ordering::SeqCst);
        } else {
            backoff.wait();
        }
    }
}

/// Terminate and drop retired processing units whose (previously
/// blocked) tasks have since finished.
fn reap_zombies(zombies: &mut Vec<(Arc<dyn ProcessingUnit>, Arc<dyn ExecutionState>)>) {
    zombies.retain(|(pu, state)| {
        if state.is_finished() {
            let _ = pu.terminate();
            false
        } else {
            true
        }
    });
}

/// The blocking-engine worker: executes each popped task on a processing
/// unit of the injected compute manager. The unit is reused across tasks
/// that run to completion; a task that blocks keeps its unit's kernel
/// thread, so the unit is retired to the zombie list and reclaimed
/// (terminated and joined) once its task finishes.
fn blocking_worker_loop(inner: Arc<Inner>, w: usize) {
    if inner.sched.pin_workers {
        crate::util::affinity::pin_to_core(inner.sched.workers[w].resource.os_index);
    }
    let mut current_pu: Option<Arc<dyn ProcessingUnit>> = None;
    let mut zombies: Vec<(Arc<dyn ProcessingUnit>, Arc<dyn ExecutionState>)> = Vec::new();
    loop {
        let next = next_runnable(&inner, w, || reap_zombies(&mut zombies));
        let Some(runnable) = next else {
            break;
        };
        let (body, node) = match runnable {
            Runnable::Fresh(body, node) => (body, node),
            Runnable::Resume(task) => {
                // Run-to-completion states never park; a Resume here is a
                // scheduler bug — fail the run loudly instead of hanging.
                debug_assert!(false, "blocking engine received a parked task");
                record_first_error(
                    &inner,
                    HicrError::InvalidState(
                        "blocking engine cannot resume a parked task".into(),
                    ),
                );
                finish_task(&inner, &task.node, Some(w));
                continue;
            }
        };
        // relaxed-ok: worker-affinity hint; a stale value only degrades victim choice
        node.home.store(w, Ordering::Relaxed);
        // Reap retired units whose (previously blocked) tasks finished
        // (also done in the idle path, so a quiesced system does not
        // hold finished executors until the next task arrives).
        reap_zombies(&mut zombies);
        if current_pu.is_none() {
            match inner
                .cm
                .create_processing_unit(&inner.sched.workers[w].resource)
            {
                Ok(pu) => current_pu = Some(pu),
                Err(e) => {
                    record_rejection(&inner, &node, &e);
                    finish_task(&inner, &node, Some(w));
                    continue;
                }
            }
        }
        let gate = Arc::new(StartGate::new());
        let inner2 = Arc::clone(&inner);
        let node2 = Arc::clone(&node);
        let gate2 = Arc::clone(&gate);
        let body_cell = std::sync::Mutex::new(Some(body));
        let unit = FnExecutionUnit::new(node.label.clone(), move |ctx| {
            let body = body_cell.lock().unwrap().take().expect("body runs once");
            let t0 = inner2.trace.now_ns();
            let tctx = TaskCtx {
                inner: &inner2,
                node: &node2,
                exec: Some(ctx),
                gate: Some(&gate2),
            };
            // Catch panics so bookkeeping always runs: a lost finish_task
            // (or an unfired gate) would hang the whole system. The panic
            // is not swallowed — it surfaces as the run()'s error.
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&tctx)));
            if outcome.is_err() {
                record_first_error(
                    &inner2,
                    HicrError::InvalidState(format!("task '{}' panicked", node2.label)),
                );
            }
            inner2.trace.record(
                // relaxed-ok: worker-affinity hint; a stale value only degrades victim choice
                node2.home.load(Ordering::Relaxed),
                EventKind::Run,
                &node2.label,
                t0,
            );
            // relaxed-ok: worker-affinity hint; a stale value only degrades victim choice
            finish_task(&inner2, &node2, Some(node2.home.load(Ordering::Relaxed)));
            gate2.fire(GateEvent::Done);
        });
        let started = inner
            .cm
            .create_execution_state(unit as Arc<dyn ExecutionUnit>)
            .and_then(|state| {
                current_pu
                    .as_ref()
                    .expect("unit ensured above")
                    .start(Arc::clone(&state))?;
                Ok(state)
            });
        match started {
            Ok(state) => match gate.wait() {
                GateEvent::Done => {
                    // Unit idle again: reuse it for the next task (the
                    // steady-state leaf path spawns no kernel thread on
                    // thread-pool backends).
                }
                GateEvent::Blocked => {
                    // The blocked task occupies the unit's executor;
                    // retire it and take a fresh unit next time.
                    zombies.push((
                        current_pu.take().expect("unit ensured above"),
                        state,
                    ));
                }
            },
            Err(e) => {
                // The manager rejected the task (wrong unit format /
                // terminated unit). Record the first rejection so the
                // enclosing `run()` fails loudly — silently dropping work
                // would report wrong results with a clean exit — while
                // keeping the graph bookkeeping sound so `run()` returns.
                record_rejection(&inner, &node, &e);
                finish_task(&inner, &node, Some(w));
            }
        }
    }
    // Shutdown (all runs quiesced): tear down the executors.
    if let Some(pu) = current_pu.take() {
        let _ = pu.terminate();
    }
    for (pu, _state) in zombies.drain(..) {
        let _ = pu.terminate();
    }
}

/// The suspending-engine worker: drives opaque suspendable
/// `dyn ExecutionState`s via `resume()` (paper §4.3 Tasking: worker
/// objects). Fresh tasks get their state created at pop time; a stolen
/// or re-enqueued task may be resumed by any worker (cross-thread resume
/// is part of the fiber substrate's contract).
fn suspending_worker_loop(inner: Arc<Inner>, w: usize) {
    if inner.sched.pin_workers {
        crate::util::affinity::pin_to_core(inner.sched.workers[w].resource.os_index);
    }
    loop {
        let Some(runnable) = next_runnable(&inner, w, || {}) else {
            return;
        };
        let task = match runnable {
            Runnable::Resume(task) => task,
            Runnable::Fresh(body, node) => {
                let inner2 = Arc::clone(&inner);
                let node2 = Arc::clone(&node);
                let body_cell = std::sync::Mutex::new(Some(body));
                let unit = FnExecutionUnit::new(node.label.clone(), move |ctx| {
                    let body =
                        body_cell.lock().unwrap().take().expect("body runs once");
                    let tctx = TaskCtx {
                        inner: &inner2,
                        node: &node2,
                        exec: Some(ctx),
                        gate: None,
                    };
                    body(&tctx);
                });
                match inner.cm.create_execution_state(unit as Arc<dyn ExecutionUnit>) {
                    Ok(state) => {
                        debug_assert!(state.supports_suspension());
                        SuspendableTask { node, state }
                    }
                    Err(e) => {
                        // Keep bookkeeping sound and surface the
                        // rejection through run().
                        record_rejection(&inner, &node, &e);
                        finish_task(&inner, &node, Some(w));
                        continue;
                    }
                }
            }
        };
        // relaxed-ok: worker-affinity hint; a stale value only degrades victim choice
        task.node.home.store(w, Ordering::Relaxed);
        let t0 = inner.trace.now_ns();
        let status = match task.state.resume() {
            Ok(s) => s,
            Err(e) => {
                record_first_error(
                    &inner,
                    HicrError::InvalidState(format!(
                        "task '{}' could not be resumed: {e}",
                        task.node.label
                    )),
                );
                ExecStatus::Failed
            }
        };
        inner
            .trace
            .record(w, EventKind::Run, &task.node.label, t0);
        match status {
            ExecStatus::Finished => {
                finish_task(&inner, &task.node, Some(w));
            }
            ExecStatus::Failed => {
                // A failed state means the task body panicked (or the
                // backend broke mid-task): surface it, don't report a
                // clean run with missing work.
                record_first_error(
                    &inner,
                    HicrError::InvalidState(format!(
                        "task '{}' failed (panicked)",
                        task.node.label
                    )),
                );
                finish_task(&inner, &task.node, Some(w));
            }
            ExecStatus::Suspended => {
                let mut sync = task.node.sync.lock();
                if sync.ready_now {
                    // Children finished before we could park.
                    sync.ready_now = false;
                    drop(sync);
                    schedule(&inner, Some(w), Runnable::Resume(task));
                } else if sync.waiting && sync.pending_children > 0 {
                    // Park; child completion re-enqueues.
                    sync.parked = Some(task.clone());
                } else {
                    // Voluntary yield.
                    drop(sync);
                    schedule(&inner, Some(w), Runnable::Resume(task));
                }
            }
            other => {
                debug_assert!(false, "unexpected resume status {other:?}");
                finish_task(&inner, &task.node, Some(w));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;
    use crate::backends::coro::CoroComputeManager;
    use crate::backends::nosv::NosvComputeManager;
    use crate::backends::threads::ThreadsComputeManager;
    use crate::core::ids::DeviceId;
    use crate::core::topology::{Device, DeviceKind};

    /// Two NUMA domains with two CPU cores each.
    fn two_numa_topology() -> Topology {
        Topology {
            devices: (0..2u32)
                .map(|d| Device {
                    id: DeviceId(d),
                    kind: DeviceKind::NumaDomain,
                    name: format!("numa{d}"),
                    memory_spaces: Vec::new(),
                    compute_resources: (0..2u64)
                        .map(|c| ComputeResource {
                            id: ComputeResourceId(u64::from(d) * 2 + c),
                            kind: "cpu-core".into(),
                            os_index: (u64::from(d) * 2 + c) as u32,
                            locality: d,
                        })
                        .collect(),
                })
                .collect(),
        }
    }

    fn coro_cm() -> Arc<dyn ComputeManager> {
        Arc::new(CoroComputeManager::new())
    }

    fn nosv_cm() -> Arc<dyn ComputeManager> {
        Arc::new(NosvComputeManager::new())
    }

    fn threads_cm() -> Arc<dyn ComputeManager> {
        Arc::new(ThreadsComputeManager::new())
    }

    fn run_tree(cm: Arc<dyn ComputeManager>) -> u64 {
        // Three-level tree: root -> 3 children -> 2 grandchildren each.
        let sys = TaskSystem::new(cm, 4, false);
        let total = Arc::new(AtomicU64::new(0));
        let t = Arc::clone(&total);
        sys.run("root", move |ctx| {
            for _ in 0..3 {
                let t = Arc::clone(&t);
                ctx.spawn("child", move |cctx| {
                    for _ in 0..2 {
                        let t = Arc::clone(&t);
                        cctx.spawn("grandchild", move |_| {
                            t.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                    cctx.wait_children();
                    t.fetch_add(10, Ordering::SeqCst);
                });
            }
            ctx.wait_children();
            t.fetch_add(100, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(sys.tasks_executed(), 10);
        total.load(Ordering::SeqCst)
    }

    #[test]
    fn suspending_engine_tree_dependencies() {
        assert_eq!(run_tree(coro_cm()), 136);
    }

    #[test]
    fn blocking_engine_tree_dependencies() {
        assert_eq!(run_tree(nosv_cm()), 136);
    }

    #[test]
    fn threads_backend_tree_dependencies() {
        // Any run-to-completion manager works — not only nosv.
        assert_eq!(run_tree(threads_cm()), 136);
    }

    #[test]
    fn global_queue_policy_still_correct() {
        // The ablation baseline funnels everything through the injection
        // lane; results must be identical, just contended.
        for cm in [coro_cm(), threads_cm()] {
            let sys = TaskSystem::with_config(
                cm,
                4,
                false,
                SchedConfig {
                    policy: SchedPolicy::GlobalQueue,
                    ..SchedConfig::default()
                },
            );
            let total = Arc::new(AtomicU64::new(0));
            let t = Arc::clone(&total);
            sys.run("root", move |ctx| {
                for _ in 0..16 {
                    let t = Arc::clone(&t);
                    ctx.spawn("leaf", move |_| {
                        t.fetch_add(1, Ordering::SeqCst);
                    });
                }
                ctx.wait_children();
            })
            .unwrap();
            sys.shutdown().unwrap();
            assert_eq!(total.load(Ordering::SeqCst), 16);
            // Every spawn took the global lane: 1 root + 16 leaves.
            assert!(sys.sched_stats().injection_pushes >= 17);
        }
    }

    /// A compute manager that rejects every execution unit (stand-in for
    /// selecting a plugin that does not prescribe host closures).
    struct RejectingCompute;

    impl ComputeManager for RejectingCompute {
        fn create_processing_unit(
            &self,
            _resource: &ComputeResource,
        ) -> Result<Arc<dyn ProcessingUnit>> {
            Err(HicrError::Unsupported("no processing units".into()))
        }

        fn create_execution_state(
            &self,
            _unit: Arc<dyn ExecutionUnit>,
        ) -> Result<Arc<dyn ExecutionState>> {
            Err(HicrError::Unsupported("no host closures".into()))
        }

        fn backend_name(&self) -> &'static str {
            "rejecting"
        }
    }

    #[test]
    fn backend_rejection_surfaces_from_run() {
        // A backend that cannot execute the task must fail the run, not
        // silently report success with dropped work.
        let sys = TaskSystem::new(Arc::new(RejectingCompute), 2, false);
        let err = sys.run("r", |_| {}).unwrap_err();
        assert!(err.to_string().contains("rejected task"), "{err}");
        sys.shutdown().unwrap();
    }

    #[test]
    fn shutdown_joins_parked_workers_even_after_error() {
        // The satellite fix: first_error set + workers parked on empty
        // deques must not prevent shutdown/Drop from joining them.
        let sys = TaskSystem::new(Arc::new(RejectingCompute), 4, false);
        assert!(sys.run("r", |_| {}).is_err());
        // Give workers time to escalate into their parked state.
        std::thread::sleep(std::time::Duration::from_millis(20));
        sys.shutdown().unwrap();
        // Idempotent: a second shutdown (and the implicit Drop) is a
        // no-op, not a hang or double-join.
        sys.shutdown().unwrap();
    }

    #[test]
    fn drop_without_shutdown_joins_workers() {
        let sys = TaskSystem::new(threads_cm(), 2, false);
        sys.run("r", |ctx| {
            ctx.spawn("c", |_| {});
            ctx.wait_children();
        })
        .unwrap();
        drop(sys); // must join, not leak or hang
    }

    #[test]
    fn engine_negotiated_from_capability() {
        let sys = TaskSystem::new(coro_cm(), 1, false);
        assert!(sys.suspending());
        assert_eq!(sys.backend_name(), "coro");
        sys.shutdown().unwrap();
        let sys = TaskSystem::new(threads_cm(), 1, false);
        assert!(!sys.suspending());
        assert_eq!(sys.backend_name(), "threads");
        sys.shutdown().unwrap();
    }

    #[test]
    fn coro_small_fibonacci() {
        // fib(10) = 55 via the naive recursive task DAG.
        let sys = TaskSystem::new(coro_cm(), 4, false);
        let result = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&result);
        sys.run("fib", move |ctx| {
            let v = fib_task(ctx, 10);
            r.store(v, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(result.load(Ordering::SeqCst), 55);
    }

    /// The naive recursive Fibonacci as nested tasks (test-local copy of
    /// the app pattern).
    fn fib_task(ctx: &TaskCtx, n: u64) -> u64 {
        if n < 2 {
            return n;
        }
        let a = Arc::new(AtomicU64::new(0));
        let b = Arc::new(AtomicU64::new(0));
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        ctx.spawn("fib-l", move |c| {
            let v = fib_task(c, n - 1);
            a2.store(v, Ordering::SeqCst);
        });
        ctx.spawn("fib-r", move |c| {
            let v = fib_task(c, n - 2);
            b2.store(v, Ordering::SeqCst);
        });
        ctx.wait_children();
        a.load(Ordering::SeqCst) + b.load(Ordering::SeqCst)
    }

    #[test]
    fn nosv_small_fibonacci() {
        let sys = TaskSystem::new(nosv_cm(), 4, false);
        let result = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&result);
        sys.run("fib", move |ctx| {
            let v = fib_task(ctx, 9);
            r.store(v, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(result.load(Ordering::SeqCst), 34);
    }

    #[test]
    fn trace_collects_task_events() {
        let sys = TaskSystem::new(coro_cm(), 2, true);
        sys.run("traced", |ctx| {
            for _ in 0..4 {
                ctx.spawn("leaf", |_| {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                });
            }
            ctx.wait_children();
        })
        .unwrap();
        sys.shutdown().unwrap();
        let events = sys.trace().events();
        assert!(events.len() >= 5, "root + 4 leaves, got {}", events.len());
        assert!(events.iter().any(|e| e.label == "leaf"));
    }

    #[test]
    fn sequential_runs_reuse_system() {
        let sys = TaskSystem::new(coro_cm(), 2, false);
        for _ in 0..3 {
            sys.run("r", |ctx| {
                ctx.spawn("c", |_| {});
                ctx.wait_children();
            })
            .unwrap();
        }
        sys.shutdown().unwrap();
        assert_eq!(sys.tasks_executed(), 6);
    }

    #[test]
    fn deep_recursion_no_worker_starvation() {
        // A chain of depth 50 where every level waits on its child: far
        // deeper than the worker count — user-level parking (coro) and
        // worker-releasing blocking waits (threads) both survive this.
        fn chain(ctx: &TaskCtx, depth: u32, hits: Arc<AtomicU64>) {
            if depth == 0 {
                hits.fetch_add(1, Ordering::SeqCst);
                return;
            }
            let h = Arc::clone(&hits);
            ctx.spawn("link", move |c| chain(c, depth - 1, h));
            ctx.wait_children();
        }
        for cm in [coro_cm(), threads_cm()] {
            let sys = TaskSystem::new(cm, 2, false);
            let hits = Arc::new(AtomicU64::new(0));
            let h = Arc::clone(&hits);
            sys.run("chain", move |ctx| chain(ctx, 50, h)).unwrap();
            sys.shutdown().unwrap();
            assert_eq!(hits.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn steady_state_spawn_is_global_lock_free() {
        // The acceptance instrument: after warmup, a root whose children
        // all spawn task-to-task must drive the injection lane exactly
        // once (the root submit) — every child push is worker-local.
        let sys = TaskSystem::new(threads_cm(), 2, false);
        sys.run("warmup", |ctx| {
            ctx.spawn("w", |_| {});
            ctx.wait_children();
        })
        .unwrap();
        let before = sys.sched_stats();
        let n = 500u64;
        let total = Arc::new(AtomicU64::new(0));
        let t = Arc::clone(&total);
        sys.run("root", move |ctx| {
            for _ in 0..n {
                let t = Arc::clone(&t);
                ctx.spawn("leaf", move |_| {
                    // relaxed-ok: telemetry counter; no data is published through this atomic
                    t.fetch_add(1, Ordering::Relaxed);
                });
            }
            ctx.wait_children();
        })
        .unwrap();
        let after = sys.sched_stats();
        sys.shutdown().unwrap();
        // relaxed-ok: telemetry counter; no data is published through this atomic
        assert_eq!(total.load(Ordering::Relaxed), n);
        assert_eq!(
            after.local_pushes - before.local_pushes,
            n,
            "every task-to-task spawn must stay on a worker-local deque"
        );
        assert_eq!(
            after.injection_pushes - before.injection_pushes,
            1,
            "only the root submit may use the injection lane"
        );
        // The global lane was locked O(1) times (root push + pop), not
        // O(n): the global-mutex ceiling is structurally gone.
        let lane_locks = after.injection_locks - before.injection_locks;
        assert!(lane_locks <= 4, "injection lane locked {lane_locks} times");
    }

    #[test]
    fn steal_storm_no_lost_or_duplicated_tasks() {
        // N workers, 1 producer: every other worker only eats via steals.
        let sys = TaskSystem::new(threads_cm(), 4, false);
        let n = 4000usize;
        let hits: Arc<Vec<AtomicU64>> =
            Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
        let before = sys.sched_stats();
        let h = Arc::clone(&hits);
        sys.run("producer", move |ctx| {
            for i in 0..n {
                let h = Arc::clone(&h);
                ctx.spawn("leaf", move |_| {
                    h[i].fetch_add(1, Ordering::SeqCst);
                });
            }
            ctx.wait_children();
        })
        .unwrap();
        let after = sys.sched_stats();
        sys.shutdown().unwrap();
        for (i, hit) in hits.iter().enumerate() {
            assert_eq!(hit.load(Ordering::SeqCst), 1, "task {i} lost or duplicated");
        }
        // Steal failures stay bounded: idle workers park instead of
        // spinning unboundedly against empty victims.
        let failures = after.steal_failures - before.steal_failures;
        assert!(failures < 2_000_000, "unbounded steal spinning: {failures}");
    }

    #[test]
    fn spawn_after_respects_dependencies() {
        for cm in [coro_cm(), threads_cm(), nosv_cm()] {
            let sys = TaskSystem::new(cm, 4, false);
            let order = Arc::new(Mutex::new(Vec::new()));
            let o = Arc::clone(&order);
            sys.run("root", move |ctx| {
                let o1 = Arc::clone(&o);
                let a = ctx.spawn("a", move |_| o1.lock().unwrap().push("a"));
                let o2 = Arc::clone(&o);
                let b = ctx.spawn("b", move |_| o2.lock().unwrap().push("b"));
                let o3 = Arc::clone(&o);
                let c = ctx.spawn_after(&[a, b], "c", move |_| {
                    o3.lock().unwrap().push("c")
                });
                let o4 = Arc::clone(&o);
                ctx.spawn_after(&[c], "d", move |_| o4.lock().unwrap().push("d"));
                ctx.wait_children();
            })
            .unwrap();
            sys.shutdown().unwrap();
            let order = order.lock().unwrap();
            assert_eq!(order.len(), 4);
            let pos = |x: &str| order.iter().position(|&v| v == x).unwrap();
            assert!(pos("c") > pos("a") && pos("c") > pos("b"));
            assert_eq!(pos("d"), 3);
        }
    }

    #[test]
    fn spawn_after_finished_dependency_fires_immediately() {
        let sys = TaskSystem::new(threads_cm(), 2, false);
        let hit = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hit);
        sys.run("root", move |ctx| {
            let a = ctx.spawn("a", |_| {});
            // Let `a` finish before the dependent is registered.
            while !a.is_finished() {
                std::thread::yield_now();
            }
            let h = Arc::clone(&h);
            ctx.spawn_after(&[a], "b", move |_| {
                h.fetch_add(1, Ordering::SeqCst);
            });
            ctx.wait_children();
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn dag_ordering_property_under_both_engines() {
        // Deterministic DAG-ordering property: on a random DAG (edges
        // only i → j with i < j), every task observes all of its
        // dependencies completed before it starts — under both the
        // suspending and blocking engines, whatever the interleaving.
        crate::prop_check!("spawn-after-dag-order", |g| {
            let n = g.sized(2, 24).max(2);
            let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
            for j in 0..n {
                let mut dj = Vec::new();
                for i in 0..j {
                    if g.rng.bool() {
                        dj.push(i);
                    }
                }
                deps.push(dj);
            }
            for cm in [coro_cm(), threads_cm()] {
                let sys = TaskSystem::new(cm, 3, false);
                let done: Arc<Vec<AtomicBool>> =
                    Arc::new((0..n).map(|_| AtomicBool::new(false)).collect());
                let violated = Arc::new(AtomicBool::new(false));
                let deps2 = deps.clone();
                let d2 = Arc::clone(&done);
                let v2 = Arc::clone(&violated);
                sys.run("dag-root", move |ctx| {
                    let mut handles: Vec<TaskHandle> = Vec::with_capacity(n);
                    for (j, dj) in deps2.iter().enumerate() {
                        let dep_handles: Vec<TaskHandle> =
                            dj.iter().map(|&i| handles[i].clone()).collect();
                        let d = Arc::clone(&d2);
                        let v = Arc::clone(&v2);
                        let dj = dj.clone();
                        let h = ctx.spawn_after(&dep_handles, "node", move |_| {
                            for &i in &dj {
                                if !d[i].load(Ordering::SeqCst) {
                                    v.store(true, Ordering::SeqCst);
                                }
                            }
                            d[j].store(true, Ordering::SeqCst);
                        });
                        handles.push(h);
                    }
                    ctx.wait_children();
                })
                .map_err(|e| e.to_string())?;
                sys.shutdown().map_err(|e| e.to_string())?;
                if violated.load(Ordering::SeqCst) {
                    return Err(format!("dependency order violated (n={n})"));
                }
                if !done.iter().all(|d| d.load(Ordering::SeqCst)) {
                    return Err(format!("lost DAG task (n={n})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dataflow_keys_gate_consumers() {
        let sys = TaskSystem::new(threads_cm(), 2, false);
        const K: u64 = 42;
        let order = Arc::new(Mutex::new(Vec::new()));
        let o = Arc::clone(&order);
        sys.run("root", move |ctx| {
            // Consumer registered first; must wait for the producer.
            let o1 = Arc::clone(&o);
            ctx.spawn_dataflow("consumer", &[K], &[], move |_| {
                o1.lock().unwrap().push("consume")
            });
            let o2 = Arc::clone(&o);
            ctx.spawn_dataflow("producer", &[], &[K], move |_| {
                o2.lock().unwrap().push("produce")
            });
            ctx.wait_children();
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(*order.lock().unwrap(), vec!["produce", "consume"]);
    }

    #[test]
    fn mark_produced_releases_external_consumers() {
        let sys = TaskSystem::new(threads_cm(), 2, false);
        const K: u64 = 7;
        let hit = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hit);
        let root = sys.submit("root", move |ctx| {
            let h = Arc::clone(&h);
            ctx.spawn_dataflow("consumer", &[K], &[], move |_| {
                h.fetch_add(1, Ordering::SeqCst);
            });
        });
        while !root.is_finished() {
            std::thread::yield_now();
        }
        assert_eq!(hit.load(Ordering::SeqCst), 0, "consumer must be gated");
        sys.mark_produced(K);
        sys.wait_idle().unwrap();
        sys.shutdown().unwrap();
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn steal_order_prefers_same_locality_ring() {
        // 4 workers over 2 NUMA domains: same-domain victims first, ring
        // rotated per thief.
        let loc = [0, 0, 1, 1];
        assert_eq!(steal_order(&loc, 0), vec![1, 2, 3]);
        assert_eq!(steal_order(&loc, 1), vec![0, 2, 3]);
        assert_eq!(steal_order(&loc, 2), vec![3, 0, 1]);
        assert_eq!(steal_order(&loc, 3), vec![2, 0, 1]);
        // Single-domain ring spreads thieves.
        assert_eq!(steal_order(&[0, 0, 0], 1), vec![2, 0]);
    }

    #[test]
    fn topology_config_assigns_worker_localities() {
        let topo = two_numa_topology();
        let sys = TaskSystem::with_config(
            threads_cm(),
            4,
            false,
            SchedConfig {
                topology: Some(topo),
                ..SchedConfig::default()
            },
        );
        let locs: Vec<u32> = sys
            .inner
            .sched
            .workers
            .iter()
            .map(|w| w.resource.locality)
            .collect();
        // Round-robin over 2 domains × 2 cores each.
        assert_eq!(locs.iter().filter(|&&l| l == 0).count(), 2);
        assert_eq!(locs.iter().filter(|&&l| l == 1).count(), 2);
        // Still runs correctly.
        let hit = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hit);
        sys.run("r", move |ctx| {
            for _ in 0..8 {
                let h = Arc::clone(&h);
                ctx.spawn("leaf", move |_| {
                    h.fetch_add(1, Ordering::SeqCst);
                });
            }
            ctx.wait_children();
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(hit.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn ready_backlog_reports_queued_tasks() {
        let sys = TaskSystem::new(threads_cm(), 1, false);
        assert_eq!(sys.ready_backlog(), 0);
        sys.run("r", |_| {}).unwrap();
        assert_eq!(sys.ready_backlog(), 0);
        sys.shutdown().unwrap();
    }
}
