//! The task system: stateful tasks, pull-scheduled workers, and the two
//! execution engines (coro fibers vs nosv thread-per-task) the paper
//! compares in Test Cases 3 and 4.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::backends::coro::compute::{CoroComputeManager, FiberExecutionState};
use crate::backends::nosv;
use crate::core::compute::{ExecStatus, ExecutionUnit, FnExecutionUnit};
use crate::core::error::{HicrError, Result};
use crate::frontends::tasking::trace::{EventKind, Trace};

/// Which engine executes the tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskSystemKind {
    /// Pthreads workers + fiber tasks (Boost.Context analogue).
    Coro,
    /// Kernel-thread-per-task with a slot-bounded system scheduler
    /// (nOS-V analogue).
    Nosv,
}

/// A task body: runs once, may spawn children and wait for them.
pub type TaskBody = Box<dyn FnOnce(&TaskCtx) + Send + 'static>;

/// Dependency/lifecycle bookkeeping shared by both engines.
struct TaskSync {
    pending_children: usize,
    waiting: bool,
    /// Set when a waiting parent became ready before it finished parking.
    ready_now: bool,
    /// Parked coro task awaiting child completion.
    parked: Option<CoroTask>,
}

struct TaskNode {
    #[allow(dead_code)]
    id: u64,
    label: String,
    parent: Option<Arc<TaskNode>>,
    sync: Mutex<TaskSync>,
    /// nosv engine: parents block here awaiting children.
    cv: Condvar,
}

#[derive(Clone)]
struct CoroTask {
    node: Arc<TaskNode>,
    fiber: Arc<FiberExecutionState>,
}

/// Counting semaphore handing out stable slot ids (nosv worker slots).
struct IdSemaphore {
    free: Mutex<Vec<usize>>,
    cv: Condvar,
}

impl IdSemaphore {
    fn new(n: usize) -> Self {
        Self {
            free: Mutex::new((0..n).rev().collect()),
            cv: Condvar::new(),
        }
    }

    fn acquire(&self) -> usize {
        let mut free = self.free.lock().unwrap();
        loop {
            if let Some(id) = free.pop() {
                return id;
            }
            free = self.cv.wait(free).unwrap();
        }
    }

    fn release(&self, id: usize) {
        self.free.lock().unwrap().push(id);
        self.cv.notify_one();
    }
}

struct CoroEngine {
    cm: CoroComputeManager,
    ready: Mutex<VecDeque<CoroTask>>,
    ready_cv: Condvar,
    shutdown: AtomicBool,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

struct NosvEngine {
    slots: IdSemaphore,
    /// Submitted-but-unscheduled tasks. nOS-V materializes a task's
    /// kernel thread when it is *scheduled*, not when submitted — eager
    /// per-submission spawning would hold thousands of live threads on a
    /// deep DAG (observed as EAGAIN at F(20); EXPERIMENTS.md §Perf).
    queue: Mutex<VecDeque<(String, TaskBody, Arc<TaskNode>)>>,
    queue_cv: Condvar,
    shutdown: AtomicBool,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

struct Inner {
    kind: TaskSystemKind,
    trace: Arc<Trace>,
    next_task_id: AtomicU64,
    outstanding: AtomicUsize,
    done_mx: Mutex<()>,
    done_cv: Condvar,
    tasks_executed: AtomicU64,
    coro: Option<CoroEngine>,
    nosv: Option<NosvEngine>,
}

/// Handle task bodies use to spawn children and synchronize (the only
/// API the Fibonacci/Jacobi apps see — engine-independent).
pub struct TaskCtx<'a> {
    inner: &'a Arc<Inner>,
    node: &'a Arc<TaskNode>,
    exec: Option<&'a crate::core::compute::ExecCtx<'a>>,
}

impl<'a> TaskCtx<'a> {
    /// Spawn a child task. The child may itself spawn and wait.
    pub fn spawn(&self, label: impl Into<String>, body: impl FnOnce(&TaskCtx) + Send + 'static) {
        {
            let mut sync = self.node.sync.lock().unwrap();
            sync.pending_children += 1;
        }
        spawn_task(
            self.inner,
            label.into(),
            Box::new(body),
            Some(Arc::clone(self.node)),
        );
    }

    /// Wait until every child spawned by this task has finished.
    pub fn wait_children(&self) {
        match self.inner.kind {
            TaskSystemKind::Coro => {
                // Park the fiber; child completion re-enqueues us.
                loop {
                    {
                        let mut sync = self.node.sync.lock().unwrap();
                        if sync.pending_children == 0 {
                            return;
                        }
                        sync.waiting = true;
                    }
                    self.exec
                        .expect("coro task without exec ctx")
                        .suspend();
                }
            }
            TaskSystemKind::Nosv => {
                // Release our scheduler slot and block the kernel thread.
                let engine = self.inner.nosv.as_ref().expect("nosv engine");
                let slot = current_nosv_slot();
                if let Some(s) = slot {
                    engine.slots.release(s);
                }
                {
                    let mut sync = self.node.sync.lock().unwrap();
                    while sync.pending_children > 0 {
                        sync = self.node.cv.wait(sync).unwrap();
                    }
                }
                if slot.is_some() {
                    let s = engine.slots.acquire();
                    set_nosv_slot(Some(s));
                }
            }
        }
    }
}

thread_local! {
    /// The nosv scheduler slot the current task thread holds.
    static NOSV_SLOT: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

fn current_nosv_slot() -> Option<usize> {
    NOSV_SLOT.with(|s| s.get())
}

fn set_nosv_slot(v: Option<usize>) {
    NOSV_SLOT.with(|s| s.set(v));
}

/// The task system facade.
pub struct TaskSystem {
    inner: Arc<Inner>,
    n_workers: usize,
}

impl TaskSystem {
    /// Create a system with `n_workers` workers/slots.
    pub fn new(kind: TaskSystemKind, n_workers: usize, trace_enabled: bool) -> Arc<TaskSystem> {
        assert!(n_workers > 0, "need at least one worker");
        let trace = Arc::new(Trace::new(trace_enabled));
        let inner = Arc::new(Inner {
            kind,
            trace,
            next_task_id: AtomicU64::new(1),
            outstanding: AtomicUsize::new(0),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
            tasks_executed: AtomicU64::new(0),
            coro: match kind {
                TaskSystemKind::Coro => Some(CoroEngine {
                    cm: CoroComputeManager::new(),
                    ready: Mutex::new(VecDeque::new()),
                    ready_cv: Condvar::new(),
                    shutdown: AtomicBool::new(false),
                    workers: Mutex::new(Vec::new()),
                }),
                TaskSystemKind::Nosv => None,
            },
            nosv: match kind {
                TaskSystemKind::Nosv => Some(NosvEngine {
                    slots: IdSemaphore::new(n_workers),
                    queue: Mutex::new(VecDeque::new()),
                    queue_cv: Condvar::new(),
                    shutdown: AtomicBool::new(false),
                    dispatcher: Mutex::new(None),
                }),
                TaskSystemKind::Coro => None,
            },
        });
        if kind == TaskSystemKind::Nosv {
            // The system-wide scheduler pump: admits queued tasks onto
            // kernel threads as slots free up.
            let inner2 = Arc::clone(&inner);
            let handle = std::thread::Builder::new()
                .name("hicr-nosv-sched".into())
                .spawn(move || nosv_dispatcher_loop(inner2))
                .expect("spawn nosv dispatcher");
            *inner.nosv.as_ref().unwrap().dispatcher.lock().unwrap() = Some(handle);
        }
        if kind == TaskSystemKind::Coro {
            // Start the pull-loop workers (paper: "a simple loop that
            // calls a pull function").
            let engine = inner.coro.as_ref().unwrap();
            let mut workers = engine.workers.lock().unwrap();
            for w in 0..n_workers {
                let inner2 = Arc::clone(&inner);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("hicr-task-worker-{w}"))
                        .spawn(move || coro_worker_loop(inner2, w))
                        .expect("spawn task worker"),
                );
            }
        }
        Arc::new(TaskSystem { inner, n_workers })
    }

    pub fn kind(&self) -> TaskSystemKind {
        self.inner.kind
    }

    pub fn trace(&self) -> Arc<Trace> {
        Arc::clone(&self.inner.trace)
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Tasks executed to completion so far.
    pub fn tasks_executed(&self) -> u64 {
        self.inner.tasks_executed.load(Ordering::Relaxed)
    }

    /// Spawn a root task and block until the whole task graph quiesces.
    pub fn run(&self, label: impl Into<String>, body: impl FnOnce(&TaskCtx) + Send + 'static) -> Result<()> {
        spawn_task(&self.inner, label.into(), Box::new(body), None);
        let mut guard = self.inner.done_mx.lock().unwrap();
        while self.inner.outstanding.load(Ordering::Acquire) != 0 {
            guard = self.inner.done_cv.wait(guard).unwrap();
        }
        Ok(())
    }

    /// Stop workers (coro) / the scheduler pump (nosv). Call after the
    /// last `run`.
    pub fn shutdown(&self) -> Result<()> {
        if let Some(engine) = &self.inner.coro {
            engine.shutdown.store(true, Ordering::SeqCst);
            engine.ready_cv.notify_all();
            let mut workers = engine.workers.lock().unwrap();
            for w in workers.drain(..) {
                w.join()
                    .map_err(|_| HicrError::InvalidState("task worker panicked".into()))?;
            }
        }
        if let Some(engine) = &self.inner.nosv {
            engine.shutdown.store(true, Ordering::SeqCst);
            engine.queue_cv.notify_all();
            if let Some(d) = engine.dispatcher.lock().unwrap().take() {
                d.join()
                    .map_err(|_| HicrError::InvalidState("nosv dispatcher panicked".into()))?;
            }
        }
        Ok(())
    }
}

/// Engine-independent task spawn.
fn spawn_task(inner: &Arc<Inner>, label: String, body: TaskBody, parent: Option<Arc<TaskNode>>) {
    inner.outstanding.fetch_add(1, Ordering::AcqRel);
    let node = Arc::new(TaskNode {
        id: inner.next_task_id.fetch_add(1, Ordering::Relaxed),
        label,
        parent,
        sync: Mutex::new(TaskSync {
            pending_children: 0,
            waiting: false,
            ready_now: false,
            parked: None,
        }),
        cv: Condvar::new(),
    });
    match inner.kind {
        TaskSystemKind::Coro => {
            let engine = inner.coro.as_ref().expect("coro engine");
            let inner2 = Arc::clone(inner);
            let node2 = Arc::clone(&node);
            let body_cell = Mutex::new(Some(body));
            let unit = FnExecutionUnit::new(node.label.clone(), move |ctx| {
                let body = body_cell.lock().unwrap().take().expect("body runs once");
                let tctx = TaskCtx {
                    inner: &inner2,
                    node: &node2,
                    exec: Some(ctx),
                };
                body(&tctx);
            });
            let fiber = engine
                .cm
                .create_fiber(unit as Arc<dyn ExecutionUnit>)
                .expect("fiber creation");
            enqueue(engine, CoroTask { node, fiber });
        }
        TaskSystemKind::Nosv => {
            // Submit to the system-wide scheduler; the dispatcher
            // materializes a kernel thread when a slot frees up.
            let engine = inner.nosv.as_ref().expect("nosv engine");
            let label = node.label.clone();
            engine.queue.lock().unwrap().push_back((label, body, node));
            engine.queue_cv.notify_one();
        }
    }
}

/// The nOS-V scheduler pump: pop a submitted task, acquire a slot, and
/// run it on a fresh kernel thread (thread-per-task at *schedule* time).
fn nosv_dispatcher_loop(inner: Arc<Inner>) {
    let engine = inner.nosv.as_ref().expect("nosv engine");
    loop {
        let next = {
            let mut queue = engine.queue.lock().unwrap();
            loop {
                if let Some(t) = queue.pop_back() {
                    break Some(t);
                }
                if engine.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = engine.queue_cv.wait(queue).unwrap();
            }
        };
        let Some((_label, body, node)) = next else { return };
        // Admission through the system-wide scheduler lock, then a slot.
        nosv::compute::admit_task();
        let slot = engine.slots.acquire();
        let inner2 = Arc::clone(&inner);
        std::thread::Builder::new()
            .name("hicr-nosv-task".into())
            .spawn(move || {
                let engine = inner2.nosv.as_ref().expect("nosv engine");
                set_nosv_slot(Some(slot));
                let t0 = inner2.trace.now_ns();
                let tctx = TaskCtx {
                    inner: &inner2,
                    node: &node,
                    exec: None,
                };
                body(&tctx);
                inner2.trace.record(
                    current_nosv_slot().unwrap_or(slot),
                    EventKind::Run,
                    &node.label,
                    t0,
                );
                if let Some(s) = current_nosv_slot() {
                    engine.slots.release(s);
                    set_nosv_slot(None);
                }
                finish_task(&inner2, &node);
            })
            .expect("spawn nosv task thread");
    }
}

fn enqueue(engine: &CoroEngine, task: CoroTask) {
    engine.ready.lock().unwrap().push_back(task);
    engine.ready_cv.notify_one();
}

/// Common completion path: notify the parent and the system.
fn finish_task(inner: &Arc<Inner>, node: &Arc<TaskNode>) {
    inner.tasks_executed.fetch_add(1, Ordering::Relaxed);
    if let Some(parent) = &node.parent {
        let to_enqueue = {
            let mut sync = parent.sync.lock().unwrap();
            sync.pending_children -= 1;
            if sync.pending_children == 0 && sync.waiting {
                sync.waiting = false;
                match sync.parked.take() {
                    Some(task) => Some(task),
                    None => {
                        // Parent not parked yet: flag it ready (coro) /
                        // wake it (nosv).
                        sync.ready_now = true;
                        None
                    }
                }
            } else {
                None
            }
        };
        parent.cv.notify_all();
        if let Some(task) = to_enqueue {
            let engine = inner.coro.as_ref().expect("parked implies coro");
            enqueue(engine, task);
        }
    }
    if inner.outstanding.fetch_sub(1, Ordering::AcqRel) == 1 {
        let _g = inner.done_mx.lock().unwrap();
        inner.done_cv.notify_all();
    }
}

/// The coro worker pull loop (paper §4.3 Tasking: worker objects).
fn coro_worker_loop(inner: Arc<Inner>, worker_id: usize) {
    let engine = inner.coro.as_ref().expect("coro engine");
    loop {
        // Pull the next ready task.
        let task = {
            let mut ready = engine.ready.lock().unwrap();
            loop {
                if let Some(t) = ready.pop_back() {
                    break Some(t);
                }
                if engine.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                ready = engine.ready_cv.wait(ready).unwrap();
            }
        };
        let Some(task) = task else { return };
        let t0 = inner.trace.now_ns();
        let status = task.fiber.resume().unwrap_or(ExecStatus::Failed);
        inner
            .trace
            .record(worker_id, EventKind::Run, &task.node.label, t0);
        match status {
            ExecStatus::Finished | ExecStatus::Failed => {
                finish_task(&inner, &task.node);
            }
            ExecStatus::Suspended => {
                let mut sync = task.node.sync.lock().unwrap();
                if sync.ready_now {
                    // Children finished before we could park.
                    sync.ready_now = false;
                    drop(sync);
                    enqueue(engine, task);
                } else if sync.waiting && sync.pending_children > 0 {
                    // Park; child completion re-enqueues.
                    sync.parked = Some(task.clone());
                } else {
                    // Voluntary yield.
                    drop(sync);
                    enqueue(engine, task);
                }
            }
            other => {
                debug_assert!(false, "unexpected fiber status {other:?}");
                finish_task(&inner, &task.node);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_tree(kind: TaskSystemKind) -> u64 {
        // Three-level tree: root -> 3 children -> 2 grandchildren each.
        let sys = TaskSystem::new(kind, 4, false);
        let total = Arc::new(AtomicU64::new(0));
        let t = Arc::clone(&total);
        sys.run("root", move |ctx| {
            for _ in 0..3 {
                let t = Arc::clone(&t);
                ctx.spawn("child", move |cctx| {
                    for _ in 0..2 {
                        let t = Arc::clone(&t);
                        cctx.spawn("grandchild", move |_| {
                            t.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                    cctx.wait_children();
                    t.fetch_add(10, Ordering::SeqCst);
                });
            }
            ctx.wait_children();
            t.fetch_add(100, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(sys.tasks_executed(), 10);
        total.load(Ordering::SeqCst)
    }

    #[test]
    fn coro_tree_dependencies() {
        assert_eq!(run_tree(TaskSystemKind::Coro), 136);
    }

    #[test]
    fn nosv_tree_dependencies() {
        assert_eq!(run_tree(TaskSystemKind::Nosv), 136);
    }

    #[test]
    fn coro_small_fibonacci() {
        // fib(10) = 55 via the naive recursive task DAG.
        let sys = TaskSystem::new(TaskSystemKind::Coro, 4, false);
        let result = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&result);
        sys.run("fib", move |ctx| {
            let v = fib_task(ctx, 10);
            r.store(v, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(result.load(Ordering::SeqCst), 55);
    }

    /// The naive recursive Fibonacci as nested tasks (test-local copy of
    /// the app pattern).
    fn fib_task(ctx: &TaskCtx, n: u64) -> u64 {
        if n < 2 {
            return n;
        }
        let a = Arc::new(AtomicU64::new(0));
        let b = Arc::new(AtomicU64::new(0));
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        ctx.spawn("fib-l", move |c| {
            let v = fib_task(c, n - 1);
            a2.store(v, Ordering::SeqCst);
        });
        ctx.spawn("fib-r", move |c| {
            let v = fib_task(c, n - 2);
            b2.store(v, Ordering::SeqCst);
        });
        ctx.wait_children();
        a.load(Ordering::SeqCst) + b.load(Ordering::SeqCst)
    }

    #[test]
    fn nosv_small_fibonacci() {
        let sys = TaskSystem::new(TaskSystemKind::Nosv, 4, false);
        let result = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&result);
        sys.run("fib", move |ctx| {
            let v = fib_task(ctx, 9);
            r.store(v, Ordering::SeqCst);
        })
        .unwrap();
        sys.shutdown().unwrap();
        assert_eq!(result.load(Ordering::SeqCst), 34);
    }

    #[test]
    fn trace_collects_task_events() {
        let sys = TaskSystem::new(TaskSystemKind::Coro, 2, true);
        sys.run("traced", |ctx| {
            for _ in 0..4 {
                ctx.spawn("leaf", |_| {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                });
            }
            ctx.wait_children();
        })
        .unwrap();
        sys.shutdown().unwrap();
        let events = sys.trace().events();
        assert!(events.len() >= 5, "root + 4 leaves, got {}", events.len());
        assert!(events.iter().any(|e| e.label == "leaf"));
    }

    #[test]
    fn sequential_runs_reuse_system() {
        let sys = TaskSystem::new(TaskSystemKind::Coro, 2, false);
        for _ in 0..3 {
            sys.run("r", |ctx| {
                ctx.spawn("c", |_| {});
                ctx.wait_children();
            })
            .unwrap();
        }
        sys.shutdown().unwrap();
        assert_eq!(sys.tasks_executed(), 6);
    }

    #[test]
    fn deep_recursion_no_worker_starvation() {
        // A chain of depth 50 where every level waits on its child: far
        // deeper than the worker count — only user-level parking survives
        // this without deadlock.
        fn chain(ctx: &TaskCtx, depth: u32, hits: Arc<AtomicU64>) {
            if depth == 0 {
                hits.fetch_add(1, Ordering::SeqCst);
                return;
            }
            let h = Arc::clone(&hits);
            ctx.spawn("link", move |c| chain(c, depth - 1, h));
            ctx.wait_children();
        }
        let sys = TaskSystem::new(TaskSystemKind::Coro, 2, false);
        let hits = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hits);
        sys.run("chain", move |ctx| chain(ctx, 50, h)).unwrap();
        sys.shutdown().unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
