//! Distributed work stealing over the RPC mesh (DESIGN.md §8).
//!
//! The PR 5 scheduler steals only *within* one instance: when a worker's
//! deque and its same-NUMA victims run dry, it backs off and parks. This
//! module extends that escalation ladder across the deployment: an
//! instance whose remote-ready lane and in-flight set are empty issues
//! **steal RPCs** over the PR 4 mesh — pull-based, initiated by the idle
//! side — before settling into its bounded park. The design composes
//! four existing layers without touching the local hot path:
//!
//! - **Descriptor tasks.** Closures cannot cross the wire, so the unit
//!   of migration is a [`DescTask`]: a pre-registered function id (the
//!   RPC farm idiom) plus argument bytes, held in an instance-level
//!   *remote-ready lane*. The local [`TaskSystem`] deques never hold
//!   descriptors; stolen work enters through the injection lane like any
//!   root task, so `steady_state_spawn_is_global_lock_free` is
//!   preserved by construction.
//! - **Steal-half batches.** A victim answers `hicr/steal/take` with
//!   ⌈lane/2⌉ tasks (capped by the thief's request and the link's
//!   payload budget), oldest first — the thief-FIFO end of the lane,
//!   mirroring the deque discipline where owners work newest-first.
//! - **Lazy payloads.** Arguments larger than
//!   [`StealConfig::lazy_threshold`] do not travel in the steal
//!   response: the victim parks them in its [`PayloadStore`] keyed by
//!   task id and ships a [`TaskPayload::Lazy`] descriptor. The thief
//!   fetches the blob point-to-point (`hicr/dataobject/fetch`) only
//!   when it actually dispatches the task — a re-stolen descriptor
//!   forwards with its original owner, so the bytes move at most once.
//! - **Topology-ordered victims.** [`StealTopology::victim_order`]
//!   prefers same-host instances before cross-fabric ones, ring-rotated
//!   by own rank so thieves spread — the NUMA-first order of
//!   `steal_order` lifted to the deployment level.
//!
//! Every blocking RPC a [`StealPool`] issues goes through
//! [`crate::frontends::rpc::RpcClient::call_pumped`], serving this
//! instance's own requests while waiting, so two instances stealing
//! from each other simultaneously make progress instead of
//! deadlocking.
//!
//! **Crash safety** (DESIGN.md §9): dataflow keys are produce-once, so
//! re-running a lost producer is safe by construction. The origin
//! retains every spawned task's `(fn_id, args)` until its completion
//! lands, and every victim records which descriptors it handed to which
//! thief; when supervision reports a peer dead
//! ([`StealPool::note_peer_lost`]) the victim re-enqueues that thief's
//! undelivered descriptors onto its own lane — rebuilding payloads the
//! dead thief had already fetched from the retained args when it is the
//! origin, or reporting them home as [`PAYLOAD_LOST`] for the origin to
//! re-spawn. A completion arriving later from a zombie executor is
//! detected in `fulfill` and discarded (produce-once means both results
//! are identical, so first-wins is correct) — counted in
//! [`SchedStats::completions_discarded`], never a loud error.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::core::error::{HicrError, Result};
use crate::frontends::dataobject::{PayloadStore, FN_FETCH};
use crate::frontends::rpc::{fn_id, RpcMesh, RpcServer};
use crate::frontends::tasking::{SchedStats, TaskSystem};
use crate::util::backoff::Backoff;
use crate::util::witness::{classes, Lock};

/// Steal RPC: hand the caller up to half of the victim's remote-ready
/// lane. Request `[u32 max_tasks][u32 thief]`; response `[u32 count]`
/// followed by `count` encoded [`DescTask`] records.
pub const FN_STEAL_TAKE: &str = "hicr/steal/take";

/// Completion RPC: deliver a finished task's result to its origin.
/// Request `[u64 id][u32 executor][u8 ok][payload…]`; empty response.
pub const FN_STEAL_COMPLETE: &str = "hicr/steal/complete";

/// Error-text prefix of a completion that means "the task did not run
/// because its lazy payload is unrecoverable" (the bytes died with a
/// crashed instance before any survivor could fetch them). The origin —
/// which retains every spawned task's argument bytes — reacts by
/// re-enqueueing the task from the retained args instead of recording a
/// failure.
pub const PAYLOAD_LOST: &str = "payload-lost:";

/// Fixed bytes of one encoded [`DescTask`] record before any inline
/// payload: `[u64 id][u64 fn_id][u32 origin][u32 owner][u32 len][u8 kind]`.
const DESC_HDR: usize = 29;

/// How a [`StealPool`] orders its victims.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Ring order by rank, topology ignored (the ablation baseline).
    Flat,
    /// Same-host victims first, each group in ring order — the NUMA-first
    /// ring of the local scheduler lifted to the deployment level.
    TopologyOrdered,
}

/// The deployment-level locality map a pool orders its victims by:
/// every member rank paired with an opaque host key (instances sharing
/// a key are "same host / same NUMA fabric"; distinct keys mean the
/// steal crosses the fabric).
#[derive(Debug, Clone)]
pub struct StealTopology {
    /// This instance's rank.
    pub me: u32,
    /// `(rank, host key)` for every world member, `me` included.
    pub hosts: Vec<(u32, u64)>,
}

impl StealTopology {
    /// A topology where every member shares one host (the in-process /
    /// simulated-hub deployments, where all instances are co-located).
    pub fn uniform(me: u32, ranks: &[u32]) -> StealTopology {
        StealTopology {
            me,
            hosts: ranks.iter().map(|&r| (r, 0)).collect(),
        }
    }

    /// Victim ranks in steal order under `policy`: peers sorted by
    /// (cross-host, ring distance from `me`) — for [`VictimPolicy::Flat`]
    /// by ring distance alone. Ring rotation by own rank spreads
    /// concurrent thieves instead of converging them on the lowest rank,
    /// exactly like the local scheduler's `steal_order`.
    pub fn victim_order(&self, policy: VictimPolicy) -> Vec<u32> {
        let mut members: Vec<u32> = self.hosts.iter().map(|&(r, _)| r).collect();
        members.sort_unstable();
        members.dedup();
        let n = members.len();
        let my_pos = members.iter().position(|&r| r == self.me).unwrap_or(0);
        let host_of = |rank: u32| -> u64 {
            self.hosts
                .iter()
                .find(|&&(r, _)| r == rank)
                .map(|&(_, h)| h)
                .unwrap_or(0)
        };
        let my_host = host_of(self.me);
        let mut peers: Vec<u32> =
            members.iter().copied().filter(|&r| r != self.me).collect();
        peers.sort_by_key(|&v| {
            let pos = members.iter().position(|&r| r == v).unwrap();
            let ring = (pos + n - my_pos) % n;
            match policy {
                VictimPolicy::Flat => (false, ring),
                VictimPolicy::TopologyOrdered => (host_of(v) != my_host, ring),
            }
        });
        peers
    }
}

/// Tuning knobs of a [`StealPool`].
#[derive(Debug, Clone, Copy)]
pub struct StealConfig {
    /// Victim ordering policy.
    pub victim_policy: VictimPolicy,
    /// Inline payloads strictly larger than this travel lazily: the
    /// bytes stay in the victim's [`PayloadStore`] and the thief fetches
    /// them only at dispatch time.
    pub lazy_threshold: usize,
    /// Upper bound on tasks requested per steal RPC (the victim further
    /// caps at half its lane and the link's payload budget).
    pub max_batch: u32,
    /// Descriptor tasks dispatched into the local [`TaskSystem`] at
    /// once. `0` resolves to `2 × n_workers` — enough to keep every
    /// worker busy plus a refill margin, small enough that a thief can
    /// still relieve this instance of a burst.
    pub max_inflight: usize,
}

impl Default for StealConfig {
    fn default() -> Self {
        Self {
            victim_policy: VictimPolicy::TopologyOrdered,
            lazy_threshold: 64,
            max_batch: 16,
            max_inflight: 0,
        }
    }
}

/// How a descriptor task's argument bytes travel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskPayload {
    /// Arguments carried in the steal response itself.
    Inline(Vec<u8>),
    /// Arguments parked in the *owner*'s [`PayloadStore`] under the task
    /// id; `len` is their size (telemetry + fetch validation).
    Lazy {
        /// Size of the parked blob in bytes.
        len: u32,
    },
}

/// A migratable task: a pre-registered function plus its arguments.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DescTask {
    /// Globally unique id: `origin rank << 32 | per-origin sequence`.
    pub id: u64,
    /// FNV-1a id of the registered function (see [`StealPool::register`]).
    pub fn_id: u64,
    /// Rank the result must be delivered to.
    pub origin: u32,
    /// Rank holding the payload (only meaningful for lazy payloads; a
    /// re-stolen descriptor forwards with its original owner).
    pub owner: u32,
    /// The argument bytes, inline or lazy.
    pub payload: TaskPayload,
}

fn encoded_len(t: &DescTask) -> usize {
    DESC_HDR
        + match &t.payload {
            TaskPayload::Inline(b) => b.len(),
            TaskPayload::Lazy { .. } => 0,
        }
}

fn encode_task(out: &mut Vec<u8>, t: &DescTask) {
    out.extend_from_slice(&t.id.to_le_bytes());
    out.extend_from_slice(&t.fn_id.to_le_bytes());
    out.extend_from_slice(&t.origin.to_le_bytes());
    out.extend_from_slice(&t.owner.to_le_bytes());
    match &t.payload {
        TaskPayload::Inline(b) => {
            out.extend_from_slice(&(b.len() as u32).to_le_bytes());
            out.push(0);
            out.extend_from_slice(b);
        }
        TaskPayload::Lazy { len } => {
            out.extend_from_slice(&len.to_le_bytes());
            out.push(1);
        }
    }
}

fn wire_err(what: &str) -> HicrError {
    HicrError::Transport(format!("malformed steal batch: {what}"))
}

fn decode_tasks(buf: &[u8]) -> Result<Vec<DescTask>> {
    if buf.len() < 4 {
        return Err(wire_err("missing count"));
    }
    let count = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    let mut tasks = Vec::with_capacity(count);
    let mut at = 4usize;
    for _ in 0..count {
        if buf.len() < at + DESC_HDR {
            return Err(wire_err("truncated record header"));
        }
        let id = u64::from_le_bytes(buf[at..at + 8].try_into().unwrap());
        let fid = u64::from_le_bytes(buf[at + 8..at + 16].try_into().unwrap());
        let origin = u32::from_le_bytes(buf[at + 16..at + 20].try_into().unwrap());
        let owner = u32::from_le_bytes(buf[at + 20..at + 24].try_into().unwrap());
        let len = u32::from_le_bytes(buf[at + 24..at + 28].try_into().unwrap());
        let kind = buf[at + 28];
        at += DESC_HDR;
        let payload = match kind {
            0 => {
                if buf.len() < at + len as usize {
                    return Err(wire_err("truncated inline payload"));
                }
                let bytes = buf[at..at + len as usize].to_vec();
                at += len as usize;
                TaskPayload::Inline(bytes)
            }
            1 => TaskPayload::Lazy { len },
            other => return Err(wire_err(&format!("unknown payload kind {other}"))),
        };
        tasks.push(DescTask {
            id,
            fn_id: fid,
            origin,
            owner,
            payload,
        });
    }
    if at != buf.len() {
        return Err(wire_err("trailing bytes after last record"));
    }
    Ok(tasks)
}

/// A finished task's result (or its error text) on its way home.
type Outcome = std::result::Result<Vec<u8>, String>;

struct Completion {
    id: u64,
    origin: u32,
    executor: u32,
    outcome: Outcome,
}

fn encode_complete(c: &Completion) -> Vec<u8> {
    let (ok, bytes): (u8, &[u8]) = match &c.outcome {
        Ok(b) => (1, b),
        Err(e) => (0, e.as_bytes()),
    };
    let mut out = Vec::with_capacity(13 + bytes.len());
    out.extend_from_slice(&c.id.to_le_bytes());
    out.extend_from_slice(&c.executor.to_le_bytes());
    out.push(ok);
    out.extend_from_slice(bytes);
    out
}

fn decode_complete(args: &[u8]) -> Result<(u64, u32, Outcome)> {
    if args.len() < 13 {
        return Err(wire_err("short completion"));
    }
    let id = u64::from_le_bytes(args[0..8].try_into().unwrap());
    let executor = u32::from_le_bytes(args[8..12].try_into().unwrap());
    let outcome = match args[12] {
        1 => Ok(args[13..].to_vec()),
        _ => Err(String::from_utf8_lossy(&args[13..]).into_owned()),
    };
    Ok((id, executor, outcome))
}

type StealHandler = Arc<dyn Fn(&[u8]) -> Result<Vec<u8>> + Send + Sync>;

/// Origin-side record of a spawned task: the result slot plus enough to
/// re-create the task from scratch (`fn_id` + argument bytes, retained
/// until the completion lands) if every copy in flight dies with a
/// crashed instance.
struct Retained {
    fn_id: u64,
    args: Vec<u8>,
    outcome: Option<Outcome>,
}

/// State shared between the drive loop, the RPC handlers, and the task
/// bodies executing on the local [`TaskSystem`]'s workers.
struct Shared {
    me: u32,
    lazy_threshold: usize,
    /// The remote-ready lane: descriptor tasks runnable here or
    /// stealable by peers. Owner side dispatches newest-first (back),
    /// thieves take oldest-first (front) — the deque discipline.
    lane: Lock<VecDeque<DescTask>>,
    /// Lock-free mirror of `lane.len()` for the drive loop's idle check.
    lane_len: AtomicUsize,
    /// Parked lazy payloads served point-to-point via `FN_FETCH`.
    store: PayloadStore,
    /// `fn_id → (name, handler)` — the pre-registered task bodies.
    handlers: Lock<HashMap<u64, (String, StealHandler)>>,
    /// Tasks *this* instance originated: retained args + result slot.
    /// Doubles as the lost/duplicated-task detector.
    outstanding: Lock<HashMap<u64, Retained>>,
    /// Originated tasks not yet completed.
    pending: AtomicUsize,
    /// Finished-here results awaiting delivery to their origins.
    completions: Lock<VecDeque<Completion>>,
    /// Descriptor tasks currently inside the local [`TaskSystem`].
    inflight: AtomicUsize,
    next_seq: AtomicU64,
    /// Tasks completed per executor rank (origin-side attribution).
    completed_by: Lock<HashMap<u32, u64>>,
    /// Victim-side crash ledger: thief rank → descriptors handed out and
    /// not yet seen completed. [`Shared::note_peer_lost`] drains a dead
    /// thief's entry back onto the lane.
    handed: Lock<HashMap<u32, HashMap<u64, DescTask>>>,
    /// Peers supervision has declared dead: never stolen from, never
    /// handed work, their queued completions dropped.
    dead: Lock<HashSet<u32>>,
    // Remote-steal telemetry (SchedStats growth).
    attempts: AtomicU64,
    successes: AtomicU64,
    migrated_in: AtomicU64,
    migrated_out: AtomicU64,
    lazy_bytes: AtomicU64,
    /// Descriptors re-enqueued after their holder crashed.
    recovered: AtomicU64,
    /// Zombie completions (unknown or already-completed ids) discarded.
    discarded: AtomicU64,
}

impl Shared {
    /// Victim side of `FN_STEAL_TAKE`: pop up to ⌈lane/2⌉ tasks (capped
    /// by the thief's request and the response `budget`), oldest first,
    /// converting over-threshold inline payloads to lazy ones parked in
    /// the store. Tasks that no longer fit the response go back to the
    /// lane front in order. Every handed-out descriptor is recorded in
    /// the per-thief crash ledger until its completion is observed; a
    /// thief already declared dead (a zombie whose request was in flight
    /// when supervision caught up) gets an empty batch.
    fn take_batch(&self, max_tasks: usize, thief: u32, budget: usize) -> Result<Vec<u8>> {
        if self.dead.lock().contains(&thief) {
            return Ok(vec![0u8; 4]);
        }
        let mut lane = self.lane.lock();
        let want = lane.len().div_ceil(2).min(max_tasks);
        let mut out = vec![0u8; 4];
        let mut taken = 0u32;
        while (taken as usize) < want {
            let Some(mut t) = lane.pop_front() else { break };
            let mut parked = 0u64;
            if let TaskPayload::Inline(bytes) = &t.payload {
                if bytes.len() > self.lazy_threshold {
                    let TaskPayload::Inline(bytes) = std::mem::replace(
                        &mut t.payload,
                        TaskPayload::Lazy {
                            len: bytes.len() as u32,
                        },
                    ) else {
                        unreachable!("matched Inline above");
                    };
                    parked = bytes.len() as u64;
                    // Publishing under a live key means a task id was
                    // duplicated — surface it, never overwrite.
                    self.store.publish(t.id, bytes)?;
                    t.owner = self.me;
                }
            }
            if out.len() + encoded_len(&t) > budget {
                lane.push_front(t);
                break;
            }
            encode_task(&mut out, &t);
            // Count lazy bytes on the victim side, when the task is
            // actually handed out: these are the bytes the steal response
            // deferred, which the thief will pull at dispatch time.
            // relaxed-ok: telemetry counter; no data is published through this atomic
            self.lazy_bytes.fetch_add(parked, Ordering::Relaxed);
            self.handed
                .lock()
                .entry(thief)
                .or_default()
                .insert(t.id, t);
            taken += 1;
        }
        // relaxed-ok: advisory mirror of lane.len(); the authoritative length is read under the lane lock
        self.lane_len.store(lane.len(), Ordering::Relaxed);
        drop(lane);
        self.migrated_out.fetch_add(taken as u64, Ordering::Relaxed);
        out[0..4].copy_from_slice(&taken.to_le_bytes());
        Ok(out)
    }

    /// Origin side: record a completed task exactly once — first wins.
    /// An unknown id or an already-completed id is a *zombie* completion
    /// (a crashed-and-recovered task's original executor resurfacing, or
    /// a double-delivery race around a crash): dataflow keys are
    /// produce-once, so both results are identical by construction and
    /// the duplicate is counted and discarded, never a loud error. A
    /// [`PAYLOAD_LOST`] failure re-enqueues the task from the retained
    /// args instead of recording a failure.
    fn fulfill(&self, id: u64, executor: u32, outcome: Outcome) {
        if matches!(&outcome, Err(m) if m.starts_with(PAYLOAD_LOST)) {
            self.respawn_from_retained(id);
            return;
        }
        let mut out = self.outstanding.lock();
        match out.get_mut(&id) {
            None | Some(Retained { outcome: Some(_), .. }) => {
                drop(out);
                // relaxed-ok: telemetry counter; no data is published through this atomic
                self.discarded.fetch_add(1, Ordering::Relaxed);
            }
            Some(r) => {
                r.outcome = Some(outcome);
                drop(out);
                self.pending.fetch_sub(1, Ordering::AcqRel);
                *self
                    .completed_by
                    .lock()
                    .entry(executor)
                    .or_insert(0) += 1;
                // The task is done: drop it from every crash ledger so a
                // later peer loss cannot re-enqueue it.
                let mut handed = self.handed.lock();
                for ledger in handed.values_mut() {
                    ledger.remove(&id);
                }
            }
        }
    }

    /// Re-enqueue an originated task from its retained args (the
    /// [`PAYLOAD_LOST`] path: every copy of the argument bytes in flight
    /// died with a crashed instance). A task already completed — the
    /// loss report raced a zombie's result — is discarded instead.
    fn respawn_from_retained(&self, id: u64) {
        let rebuilt = {
            let out = self.outstanding.lock();
            match out.get(&id) {
                Some(Retained { outcome: None, fn_id, args }) => Some(DescTask {
                    id,
                    fn_id: *fn_id,
                    origin: self.me,
                    owner: self.me,
                    payload: TaskPayload::Inline(args.clone()),
                }),
                _ => None,
            }
        };
        match rebuilt {
            Some(t) => {
                // relaxed-ok: telemetry counter; no data is published through this atomic
                self.recovered.fetch_add(1, Ordering::Relaxed);
                self.push_lane_back(vec![t]);
            }
            None => {
                // relaxed-ok: telemetry counter; no data is published through this atomic
                self.discarded.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Supervision input: `rank` is dead. Quarantine it (no more steals
    /// from it, no more work handed to it, its queued completions
    /// dropped) and re-enqueue every descriptor it was holding. Returns
    /// the number of tasks recovered onto the lane; idempotent — a
    /// second report of the same rank is a no-op.
    ///
    /// Payload recovery per descriptor: inline payloads travel in the
    /// ledger entry and re-enqueue as-is. Lazy payloads this instance
    /// owns are pulled back from the [`PayloadStore`] — unless the dead
    /// thief already fetched them, in which case the bytes are rebuilt
    /// from the retained args when this instance is also the origin, or
    /// reported home as [`PAYLOAD_LOST`] otherwise (the origin re-spawns
    /// from its own retained copy). Lazy payloads owned elsewhere
    /// forward unchanged; if the owner has also lost the bytes the fetch
    /// at dispatch time degrades into the same [`PAYLOAD_LOST`] report.
    fn note_peer_lost(&self, rank: u32) -> u64 {
        if !self.dead.lock().insert(rank) {
            return 0;
        }
        let ledger = self
            .handed
            .lock()
            .remove(&rank)
            .unwrap_or_default();
        let mut requeue = Vec::new();
        for (_, mut t) in ledger {
            match &t.payload {
                TaskPayload::Inline(_) => requeue.push(t),
                TaskPayload::Lazy { .. } if t.owner == self.me => {
                    if let Some(bytes) = self.store.take(t.id) {
                        t.payload = TaskPayload::Inline(bytes);
                        requeue.push(t);
                    } else if t.origin == self.me {
                        self.respawn_from_retained(t.id);
                    } else {
                        self.completions.lock().push_back(Completion {
                            id: t.id,
                            origin: t.origin,
                            executor: self.me,
                            outcome: Err(format!(
                                "{PAYLOAD_LOST} task {:#x}: payload died \
                                 with instance {rank} before any survivor \
                                 fetched it",
                                t.id
                            )),
                        });
                    }
                }
                TaskPayload::Lazy { .. } => requeue.push(t),
            }
        }
        let n = requeue.len() as u64;
        // relaxed-ok: telemetry counter; no data is published through this atomic
        self.recovered.fetch_add(n, Ordering::Relaxed);
        self.push_lane_back(requeue);
        n
    }

    fn push_lane_back(&self, tasks: Vec<DescTask>) {
        let mut lane = self.lane.lock();
        lane.extend(tasks);
        // relaxed-ok: advisory mirror of lane.len(); the authoritative length is read under the lane lock
        self.lane_len.store(lane.len(), Ordering::Relaxed);
    }
}

/// Instance-level distributed stealing pool: a remote-ready lane of
/// descriptor tasks in front of a local [`TaskSystem`], wired into the
/// deployment's [`RpcMesh`]. See the module docs for the protocol.
pub struct StealPool {
    sys: Arc<TaskSystem>,
    shared: Arc<Shared>,
    /// Victim ranks in steal order (fixed at construction).
    victims: Vec<u32>,
    max_batch: u32,
    max_inflight: usize,
}

impl StealPool {
    /// Build a pool executing on `sys`, stealing per `topo` and `config`.
    /// Call [`StealPool::install`] on the deployment's server before
    /// driving, and register every task function on every instance.
    pub fn new(
        sys: Arc<TaskSystem>,
        topo: &StealTopology,
        config: StealConfig,
    ) -> StealPool {
        let max_inflight = if config.max_inflight == 0 {
            2 * sys.n_workers()
        } else {
            config.max_inflight
        };
        StealPool {
            shared: Arc::new(Shared {
                me: topo.me,
                lazy_threshold: config.lazy_threshold,
                lane: Lock::new(&classes::STEAL_LANE, VecDeque::new()),
                lane_len: AtomicUsize::new(0),
                store: PayloadStore::new(),
                handlers: Lock::new(&classes::STEAL_HANDLERS, HashMap::new()),
                outstanding: Lock::new(&classes::STEAL_OUTSTANDING, HashMap::new()),
                pending: AtomicUsize::new(0),
                completions: Lock::new(&classes::STEAL_COMPLETIONS, VecDeque::new()),
                inflight: AtomicUsize::new(0),
                next_seq: AtomicU64::new(0),
                completed_by: Lock::new(&classes::STEAL_COMPLETED_BY, HashMap::new()),
                handed: Lock::new(&classes::STEAL_HANDED, HashMap::new()),
                dead: Lock::new(&classes::STEAL_DEAD, HashSet::new()),
                attempts: AtomicU64::new(0),
                successes: AtomicU64::new(0),
                migrated_in: AtomicU64::new(0),
                migrated_out: AtomicU64::new(0),
                lazy_bytes: AtomicU64::new(0),
                recovered: AtomicU64::new(0),
                discarded: AtomicU64::new(0),
            }),
            victims: topo.victim_order(config.victim_policy),
            max_batch: config.max_batch,
            max_inflight,
            sys,
        }
    }

    /// Pre-register the task body callable as `name` (every instance
    /// must register the same names — the RPC farm idiom). Duplicate
    /// names and fn-id collisions are rejected loudly.
    pub fn register(
        &self,
        name: &str,
        f: impl Fn(&[u8]) -> Result<Vec<u8>> + Send + Sync + 'static,
    ) -> Result<()> {
        let id = fn_id(name);
        let mut handlers = self.shared.handlers.lock();
        if let Some((existing, _)) = handlers.get(&id) {
            return Err(HicrError::Rejected(if existing == name {
                format!("steal task '{name}' already registered")
            } else {
                format!(
                    "steal task fn_id collision: '{name}' hashes to {id:#018x}, \
                     already taken by '{existing}'"
                )
            }));
        }
        handlers.insert(id, (name.to_string(), Arc::new(f)));
        Ok(())
    }

    /// Register the steal fn-id family (`FN_STEAL_TAKE`,
    /// `FN_STEAL_COMPLETE`, `FN_FETCH`) on the deployment's server —
    /// the world-bring-up step that makes this instance a valid victim,
    /// origin, and payload owner.
    pub fn install(&self, server: &mut RpcServer) -> Result<()> {
        let budget = server.max_payload();
        let shared = Arc::clone(&self.shared);
        server.register(FN_STEAL_TAKE, move |args| {
            if args.len() != 8 {
                return Err(HicrError::Bounds(format!(
                    "steal-take request must be 8 B, got {}",
                    args.len()
                )));
            }
            let max_tasks = u32::from_le_bytes(args[0..4].try_into().unwrap());
            let thief = u32::from_le_bytes(args[4..8].try_into().unwrap());
            shared.take_batch(max_tasks as usize, thief, budget)
        })?;
        let shared = Arc::clone(&self.shared);
        server.register(FN_STEAL_COMPLETE, move |args| {
            let (id, executor, outcome) = decode_complete(args)?;
            shared.fulfill(id, executor, outcome);
            Ok(Vec::new())
        })?;
        self.shared.store.register_fetch(server)
    }

    /// Enqueue a task for `name` (which must be registered) with `args`
    /// onto the remote-ready lane and return its id. The task runs here
    /// unless a thief takes it first; fetch the result with
    /// [`StealPool::take_result`] after driving.
    pub fn spawn(&self, name: &str, args: Vec<u8>) -> Result<u64> {
        let fid = fn_id(name);
        if !self.shared.handlers.lock().contains_key(&fid) {
            return Err(HicrError::Rejected(format!(
                "steal task '{name}' spawned before registration"
            )));
        }
        // relaxed-ok: unique-id allocation; only atomicity matters, no payload is published
        let seq = self.shared.next_seq.fetch_add(1, Ordering::Relaxed);
        let id = (self.shared.me as u64) << 32 | seq;
        // Retain the args until the completion lands: the raw material
        // for re-spawning if every in-flight copy dies (DESIGN.md §9).
        self.shared.outstanding.lock().insert(
            id,
            Retained {
                fn_id: fid,
                args: args.clone(),
                outcome: None,
            },
        );
        self.shared.pending.fetch_add(1, Ordering::AcqRel);
        self.shared.push_lane_back(vec![DescTask {
            id,
            fn_id: fid,
            origin: self.shared.me,
            owner: self.shared.me,
            payload: TaskPayload::Inline(args),
        }]);
        Ok(id)
    }

    /// Tasks this instance originated that have not completed yet.
    pub fn pending(&self) -> usize {
        self.shared.pending.load(Ordering::Acquire)
    }

    /// Supervision input: `rank` crashed. Quarantines the peer (no more
    /// steals from it, no work handed to it, its queued completions
    /// dropped) and re-enqueues every descriptor the victim-side crash
    /// ledger says it was holding — rebuilding lazy payloads the dead
    /// thief had already fetched from the retained args, or reporting
    /// them home as [`PAYLOAD_LOST`]. Returns the number of descriptors
    /// recovered onto the lane; idempotent per rank.
    pub fn note_peer_lost(&self, rank: u32) -> u64 {
        self.shared.note_peer_lost(rank)
    }

    /// Descriptors re-enqueued after a holder crashed (both ledger
    /// replays and [`PAYLOAD_LOST`] re-spawns) — the `recovered=` figure
    /// the taskfarm summary reports.
    pub fn recovered(&self) -> u64 {
        // relaxed-ok: telemetry counter; no data is published through this atomic
        self.shared.recovered.load(Ordering::Relaxed)
    }

    /// Descriptor tasks currently queued on the remote-ready lane.
    pub fn lane_len(&self) -> usize {
        // relaxed-ok: advisory mirror of lane.len(); the authoritative length is read under the lane lock
        self.shared.lane_len.load(Ordering::Relaxed)
    }

    /// Take the result of an originated task: `Ok(None)` while it is
    /// still running (or for an unknown/already-taken id); a task whose
    /// body failed surfaces its error.
    pub fn take_result(&self, id: u64) -> Result<Option<Vec<u8>>> {
        let mut out = self.shared.outstanding.lock();
        match out.get(&id) {
            None | Some(Retained { outcome: None, .. }) => Ok(None),
            Some(Retained { outcome: Some(_), .. }) => {
                let outcome = out.remove(&id).unwrap().outcome.unwrap();
                drop(out);
                outcome.map(Some).map_err(|e| {
                    HicrError::InvalidState(format!(
                        "steal task {id:#x} failed remotely: {e}"
                    ))
                })
            }
        }
    }

    /// Tasks completed per executor rank, as observed by this origin
    /// (rank `me` entries are tasks that ran locally). Sorted by rank.
    pub fn completed_by(&self) -> Vec<(u32, u64)> {
        let mut v: Vec<(u32, u64)> = self
            .shared
            .completed_by
            .lock()
            .iter()
            .map(|(&r, &c)| (r, c))
            .collect();
        v.sort_unstable();
        v
    }

    /// Local scheduler counters merged with this pool's remote-steal
    /// telemetry (the `SchedStats` growth of PR 7).
    pub fn sched_stats(&self) -> SchedStats {
        let s = &self.shared;
        SchedStats {
            // relaxed-ok: telemetry counter; no data is published through this atomic
            remote_steal_attempts: s.attempts.load(Ordering::Relaxed),
            remote_steals: s.successes.load(Ordering::Relaxed),
            tasks_migrated_in: s.migrated_in.load(Ordering::Relaxed),
            tasks_migrated_out: s.migrated_out.load(Ordering::Relaxed),
            // relaxed-ok: telemetry counter; no data is published through this atomic
            lazy_payload_bytes: s.lazy_bytes.load(Ordering::Relaxed),
            tasks_recovered: s.recovered.load(Ordering::Relaxed),
            completions_discarded: s.discarded.load(Ordering::Relaxed),
            ..self.sys.sched_stats()
        }
    }

    /// Drive this instance's side of the protocol until `keep` returns
    /// false: deliver finished results, dispatch lane tasks into the
    /// local [`TaskSystem`], answer peers' requests, and — once the lane
    /// and the in-flight set are empty — escalate to remote stealing
    /// before settling into the bounded park (capped [`Backoff`]
    /// sleeps). `keep` is also the cancel signal for in-flight steal
    /// RPCs, so a shutdown served mid-steal aborts the wait cleanly.
    pub fn drive_while(
        &self,
        mesh: &mut RpcMesh,
        mut keep: impl FnMut() -> bool,
    ) -> Result<()> {
        let RpcMesh {
            server, clients, ..
        } = mesh;
        let mut backoff = Backoff::new();
        while keep() {
            // Ship finished results home and refill the local system.
            let mut progress = self.flush_completions(server, clients)?;
            if self.dispatch_ready(server, clients)? {
                progress = true;
            }
            // Answer peers (steal-takes, fetches, completions, shutdown).
            while server.try_serve_one()? {
                progress = true;
            }
            if progress {
                backoff.reset();
                continue;
            }
            // Escalation: local lane and in-flight set empty — try the
            // victims in topology order before parking.
            // relaxed-ok: advisory mirror of lane.len(); the authoritative length is read under the lane lock
            if self.shared.lane_len.load(Ordering::Relaxed) == 0
                && self.shared.inflight.load(Ordering::Acquire) == 0
                && !self.victims.is_empty()
            {
                let stole = self.steal_round(server, clients, &mut keep)?;
                if stole {
                    backoff.reset();
                    continue;
                }
            }
            // Bounded park: capped sleeps, still re-polling everything.
            backoff.wait();
        }
        Ok(())
    }

    /// True when this instance has nothing left to drive: no originated
    /// task pending, an empty lane, no in-flight dispatches, and no
    /// undelivered completions. This is the drain condition of
    /// [`StealPool::drive_until_drained`], exposed so callers can run a
    /// *supervised* drain — their own [`StealPool::drive_while`]
    /// predicate that also polls a failure detector between rounds and
    /// feeds [`StealPool::note_peer_lost`].
    pub fn drained(&self) -> bool {
        self.shared.pending.load(Ordering::Acquire) == 0
            // relaxed-ok: advisory mirror of lane.len(); the authoritative length is read under the lane lock
            && self.shared.lane_len.load(Ordering::Relaxed) == 0
            && self.shared.inflight.load(Ordering::Acquire) == 0
            && self.shared.completions.lock().is_empty()
    }

    /// Drive until every task this instance originated has completed
    /// and every foreign result has been delivered (the root's side of
    /// a drain).
    pub fn drive_until_drained(&self, mesh: &mut RpcMesh) -> Result<()> {
        self.drive_while(mesh, || !self.drained())
    }

    /// Deliver queued completions: local fulfillment for own tasks, a
    /// pumped `FN_STEAL_COMPLETE` call home for stolen ones. Results
    /// whose origin is dead are dropped (there is nowhere to deliver
    /// them — the origin's retained-args ledger died with it); a
    /// delivery that times out is re-queued and retried next round, so
    /// an origin that is merely slow (or about to be declared dead)
    /// never wedges the drive loop.
    fn flush_completions(
        &self,
        server: &mut RpcServer,
        clients: &mut std::collections::BTreeMap<u32, crate::frontends::rpc::RpcClient>,
    ) -> Result<bool> {
        let mut progress = false;
        loop {
            // Popped in its own statement so the lane lock never spans
            // the pumped delivery call below.
            let next = self.shared.completions.lock().pop_front();
            let Some(c) = next else { break };
            if c.origin == self.shared.me {
                self.shared.fulfill(c.id, c.executor, c.outcome);
            } else if self.shared.dead.lock().contains(&c.origin) {
                // relaxed-ok: telemetry counter; no data is published through this atomic
                self.shared.discarded.fetch_add(1, Ordering::Relaxed);
            } else {
                let payload = encode_complete(&c);
                let client = clients.get_mut(&c.origin).ok_or_else(|| {
                    HicrError::Rejected(format!(
                        "no RPC link to origin {} of task {:#x}",
                        c.origin, c.id
                    ))
                })?;
                match client.call_pumped(
                    FN_STEAL_COMPLETE,
                    &payload,
                    || server.try_serve_one(),
                    || false,
                ) {
                    Ok(r) => {
                        r.expect("uncancelable call");
                    }
                    Err(e) if e.is_peer_failure() => {
                        // In doubt: requeue and stop flushing this round.
                        // If the origin really is dead, supervision will
                        // mark it and the retry drops the result instead.
                        self.shared.completions.lock().push_back(c);
                        break;
                    }
                    Err(e) => return Err(e),
                }
            }
            progress = true;
        }
        Ok(progress)
    }

    /// Move lane tasks (newest first — the owner side of the deque
    /// discipline) into the local [`TaskSystem`], fetching lazy payloads
    /// at dispatch time, up to the in-flight cap.
    fn dispatch_ready(
        &self,
        server: &mut RpcServer,
        clients: &mut std::collections::BTreeMap<u32, crate::frontends::rpc::RpcClient>,
    ) -> Result<bool> {
        let mut progress = false;
        while self.shared.inflight.load(Ordering::Acquire) < self.max_inflight {
            let task = {
                let mut lane = self.shared.lane.lock();
                let t = lane.pop_back();
                // relaxed-ok: advisory mirror of lane.len(); the authoritative length is read under the lane lock
                self.shared.lane_len.store(lane.len(), Ordering::Relaxed);
                t
            };
            let Some(t) = task else { break };
            let args = match t.payload {
                TaskPayload::Inline(bytes) => bytes,
                TaskPayload::Lazy { len } => {
                    let fetched: Result<Vec<u8>> = if t.owner == self.shared.me {
                        self.shared.store.take(t.id).ok_or_else(|| {
                            HicrError::InvalidState(format!(
                                "lazy payload of own task {:#x} missing",
                                t.id
                            ))
                        })
                    } else if self.shared.dead.lock().contains(&t.owner) {
                        Err(HicrError::PeerLost(format!(
                            "payload owner {} of task {:#x} is dead",
                            t.owner, t.id
                        )))
                    } else {
                        let client =
                            clients.get_mut(&t.owner).ok_or_else(|| {
                                HicrError::Rejected(format!(
                                    "no RPC link to payload owner {} of task {:#x}",
                                    t.owner, t.id
                                ))
                            })?;
                        client
                            .call_pumped(
                                FN_FETCH,
                                &t.id.to_le_bytes(),
                                || server.try_serve_one(),
                                || false,
                            )
                            .map(|r| r.expect("uncancelable call"))
                    };
                    match fetched {
                        Ok(bytes) if bytes.len() == len as usize => bytes,
                        Ok(bytes) => {
                            return Err(HicrError::Transport(format!(
                                "task {:#x}: lazy payload is {} B, descriptor \
                                 promised {len} B",
                                t.id,
                                bytes.len()
                            )));
                        }
                        // A foreign payload that cannot be pulled (owner
                        // dead, fetch timed out, or the blob already
                        // consumed by a crashed thief) is unrecoverable
                        // from here: report it home so the origin
                        // re-spawns the task from its retained args.
                        Err(e) if t.owner != self.shared.me => {
                            self.shared.completions.lock().push_back(
                                Completion {
                                    id: t.id,
                                    origin: t.origin,
                                    executor: self.shared.me,
                                    outcome: Err(format!(
                                        "{PAYLOAD_LOST} task {:#x}: fetch \
                                         from owner {} failed: {e}",
                                        t.id, t.owner
                                    )),
                                },
                            );
                            progress = true;
                            continue;
                        }
                        Err(e) => return Err(e),
                    }
                }
            };
            let handler = {
                let handlers = self.shared.handlers.lock();
                let (_, h) = handlers.get(&t.fn_id).ok_or_else(|| {
                    HicrError::Rejected(format!(
                        "stolen task {:#x} references unregistered fn \
                         {:#018x} (register the same names on every instance)",
                        t.id, t.fn_id
                    ))
                })?;
                Arc::clone(h)
            };
            let shared = Arc::clone(&self.shared);
            let (id, origin) = (t.id, t.origin);
            self.shared.inflight.fetch_add(1, Ordering::AcqRel);
            self.sys.submit("steal-task", move |_| {
                let outcome = handler(&args).map_err(|e| e.to_string());
                shared.completions.lock().push_back(Completion {
                    id,
                    origin,
                    executor: shared.me,
                    outcome,
                });
                shared.inflight.fetch_sub(1, Ordering::AcqRel);
            });
            progress = true;
        }
        Ok(progress)
    }

    /// One scan over the victims in topology order; returns whether any
    /// steal landed tasks on the lane. `keep` doubles as the cancel
    /// predicate: a shutdown observed mid-call abandons the round. Dead
    /// victims are skipped; a victim that times out mid-steal is simply
    /// passed over this round (it is either slow — try again later — or
    /// about to be declared dead by supervision).
    fn steal_round(
        &self,
        server: &mut RpcServer,
        clients: &mut std::collections::BTreeMap<u32, crate::frontends::rpc::RpcClient>,
        keep: &mut impl FnMut() -> bool,
    ) -> Result<bool> {
        let mut req = [0u8; 8];
        req[0..4].copy_from_slice(&self.max_batch.to_le_bytes());
        req[4..8].copy_from_slice(&self.shared.me.to_le_bytes());
        for &victim in &self.victims {
            if self.shared.dead.lock().contains(&victim) {
                continue;
            }
            // relaxed-ok: telemetry counter; no data is published through this atomic
            self.shared.attempts.fetch_add(1, Ordering::Relaxed);
            let client = clients.get_mut(&victim).ok_or_else(|| {
                HicrError::Rejected(format!("no RPC link to victim {victim}"))
            })?;
            let resp = match client.call_pumped(
                FN_STEAL_TAKE,
                &req,
                || server.try_serve_one(),
                || !keep(),
            ) {
                Ok(Some(resp)) => resp,
                Ok(None) => return Ok(false), // canceled (e.g. shutdown mid-steal)
                Err(e) if e.is_peer_failure() => continue,
                Err(e) => return Err(e),
            };
            let tasks = decode_tasks(&resp)?;
            if !tasks.is_empty() {
                // relaxed-ok: telemetry counter; no data is published through this atomic
                self.shared.successes.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .migrated_in
                    .fetch_add(tasks.len() as u64, Ordering::Relaxed);
                self.shared.push_lane_back(tasks);
                return Ok(true);
            }
        }
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::threads::ThreadsCommunicationManager;
    use crate::core::communication::CommunicationManager;
    use crate::core::ids::MemorySpaceId;
    use crate::core::memory::LocalMemorySlot;
    use std::sync::atomic::AtomicBool;

    fn alloc(len: usize) -> Result<LocalMemorySlot> {
        LocalMemorySlot::alloc(MemorySpaceId(1), len)
    }

    fn task_system(workers: usize) -> Arc<TaskSystem> {
        TaskSystem::new(
            Arc::new(crate::backends::threads::ThreadsComputeManager::new()),
            workers,
            false,
        )
    }

    /// Satellite: same-host victims come before cross-fabric ones, both
    /// groups ring-rotated past own rank; Flat ignores the hosts.
    #[test]
    fn victim_order_prefers_same_host_before_cross_fabric() {
        let topo = StealTopology {
            me: 0,
            hosts: vec![(0, 0xA), (1, 0xB), (2, 0xA), (3, 0xB), (4, 0xA)],
        };
        assert_eq!(
            topo.victim_order(VictimPolicy::TopologyOrdered),
            vec![2, 4, 1, 3]
        );
        assert_eq!(topo.victim_order(VictimPolicy::Flat), vec![1, 2, 3, 4]);
    }

    /// Ring rotation: a middle rank scans forward first, wrapping, so
    /// concurrent thieves spread instead of converging on rank 0.
    #[test]
    fn victim_order_ring_rotates_past_own_rank() {
        let topo = StealTopology::uniform(2, &[0, 1, 2, 3, 4]);
        assert_eq!(
            topo.victim_order(VictimPolicy::TopologyOrdered),
            vec![3, 4, 0, 1]
        );
        // Same-host grouping survives the rotation.
        let topo = StealTopology {
            me: 2,
            hosts: vec![(0, 7), (1, 9), (2, 7), (3, 9), (4, 7)],
        };
        assert_eq!(
            topo.victim_order(VictimPolicy::TopologyOrdered),
            vec![4, 0, 3, 1]
        );
    }

    #[test]
    fn task_wire_roundtrip() {
        let tasks = vec![
            DescTask {
                id: 0x1_0000_0007,
                fn_id: fn_id("t/a"),
                origin: 1,
                owner: 1,
                payload: TaskPayload::Inline(vec![1, 2, 3]),
            },
            DescTask {
                id: 0x2_0000_0009,
                fn_id: fn_id("t/b"),
                origin: 2,
                owner: 3,
                payload: TaskPayload::Lazy { len: 4096 },
            },
        ];
        let mut buf = vec![0u8; 4];
        for t in &tasks {
            encode_task(&mut buf, t);
        }
        buf[0..4].copy_from_slice(&2u32.to_le_bytes());
        assert_eq!(decode_tasks(&buf).unwrap(), tasks);
        // Truncations and garbage kinds are wire errors, not panics.
        assert!(decode_tasks(&buf[..buf.len() - 1]).is_err());
        assert!(decode_tasks(&[]).is_err());
        let mut bad = buf.clone();
        bad[4 + DESC_HDR - 1] = 9;
        assert!(decode_tasks(&bad).is_err());
    }

    /// Steal-half on the victim lane: 7 queued → 4 handed out (oldest
    /// first), 3 kept; over-threshold payloads convert to lazy records
    /// parked in the store.
    #[test]
    fn take_batch_steals_half_and_parks_large_payloads() {
        let sys = task_system(1);
        let topo = StealTopology::uniform(0, &[0, 1]);
        let pool = StealPool::new(
            Arc::clone(&sys),
            &topo,
            StealConfig {
                lazy_threshold: 8,
                ..StealConfig::default()
            },
        );
        pool.register("t/echo", |a| Ok(a.to_vec())).unwrap();
        for i in 0..7u64 {
            // Task 0 gets a big payload (lazy), the rest stay inline.
            let len = if i == 0 { 32 } else { 4 };
            pool.spawn("t/echo", vec![i as u8; len]).unwrap();
        }
        let batch = pool.shared.take_batch(16, 1, 32 * 1024).unwrap();
        let tasks = decode_tasks(&batch).unwrap();
        assert_eq!(tasks.len(), 4, "ceil(7/2)");
        assert_eq!(pool.lane_len(), 3);
        assert_eq!(tasks[0].payload, TaskPayload::Lazy { len: 32 });
        assert_eq!(tasks[0].owner, 0);
        assert_eq!(pool.shared.store.take(tasks[0].id).unwrap(), vec![0u8; 32]);
        assert!(matches!(tasks[1].payload, TaskPayload::Inline(_)));
        // The thief's cap is honored too.
        let batch = pool.shared.take_batch(1, 1, 32 * 1024).unwrap();
        assert_eq!(decode_tasks(&batch).unwrap().len(), 1);
        sys.shutdown().unwrap();
    }

    /// A response budget too small for the whole half re-queues the
    /// overflow at the lane front in order — tasks are never dropped.
    #[test]
    fn take_batch_respects_response_budget() {
        let sys = task_system(1);
        let topo = StealTopology::uniform(0, &[0, 1]);
        let pool = StealPool::new(Arc::clone(&sys), &topo, StealConfig::default());
        pool.register("t/echo", |a| Ok(a.to_vec())).unwrap();
        for i in 0..8u64 {
            pool.spawn("t/echo", vec![i as u8; 16]).unwrap();
        }
        // Budget fits the count word + two 45-byte records only.
        let batch = pool.shared.take_batch(16, 1, 4 + 2 * (DESC_HDR + 16)).unwrap();
        let tasks = decode_tasks(&batch).unwrap();
        assert_eq!(tasks.len(), 2);
        assert_eq!(pool.lane_len(), 6);
        // The overflow kept its order: the next take starts at task 2.
        let batch = pool.shared.take_batch(16, 1, 32 * 1024).unwrap();
        let next = decode_tasks(&batch).unwrap();
        assert_eq!(next[0].payload, TaskPayload::Inline(vec![2u8; 16]));
        sys.shutdown().unwrap();
    }

    /// Crash semantics: unknown and duplicate completions are zombies —
    /// counted and discarded, never loud errors (produce-once makes
    /// first-wins correct; DESIGN.md §9). The first result stands.
    #[test]
    fn fulfill_discards_unknown_and_duplicate_completions() {
        let sys = task_system(1);
        let topo = StealTopology::uniform(0, &[0, 1]);
        let pool = StealPool::new(Arc::clone(&sys), &topo, StealConfig::default());
        pool.register("t/echo", |a| Ok(a.to_vec())).unwrap();
        let id = pool.spawn("t/echo", vec![1]).unwrap();
        pool.shared.fulfill(999, 1, Ok(vec![])); // unknown id: zombie
        pool.shared.fulfill(id, 1, Ok(vec![7])); // first wins
        pool.shared.fulfill(id, 2, Ok(vec![8])); // duplicate: discarded
        assert_eq!(pool.sched_stats().completions_discarded, 2);
        assert_eq!(pool.take_result(id).unwrap(), Some(vec![7]));
        assert_eq!(pool.pending(), 0);
        sys.shutdown().unwrap();
    }

    /// The crash ledger end to end: a thief dies holding stolen
    /// descriptors; the victim re-enqueues them all — inline ones
    /// as-is, the lazy one pulled back from the store — and refuses to
    /// hand the zombie more work afterwards.
    #[test]
    fn lost_thief_descriptors_requeue_onto_the_lane() {
        let sys = task_system(1);
        let topo = StealTopology::uniform(0, &[0, 1]);
        let pool = StealPool::new(
            Arc::clone(&sys),
            &topo,
            StealConfig {
                lazy_threshold: 8,
                ..StealConfig::default()
            },
        );
        pool.register("t/echo", |a| Ok(a.to_vec())).unwrap();
        pool.spawn("t/echo", vec![9u8; 32]).unwrap(); // lazy when stolen
        for i in 1..6u64 {
            pool.spawn("t/echo", vec![i as u8; 4]).unwrap();
        }
        let batch = pool.shared.take_batch(16, 1, 32 * 1024).unwrap();
        assert_eq!(decode_tasks(&batch).unwrap().len(), 3, "ceil(6/2)");
        assert_eq!(pool.lane_len(), 3);
        assert_eq!(pool.shared.store.len(), 1, "lazy payload parked");
        // Thief 1 crashes before delivering anything.
        assert_eq!(pool.note_peer_lost(1), 3);
        assert_eq!(pool.lane_len(), 6, "everything back on the lane");
        assert!(pool.shared.store.is_empty(), "lazy bytes reclaimed");
        assert_eq!(pool.recovered(), 3);
        assert_eq!(pool.pending(), 6, "nothing lost or double-counted");
        // Idempotent, and the zombie gets no more work.
        assert_eq!(pool.note_peer_lost(1), 0);
        let empty = pool.shared.take_batch(16, 1, 32 * 1024).unwrap();
        assert!(decode_tasks(&empty).unwrap().is_empty());
        // The requeued lazy task is inline again, payload intact.
        let lane = pool.shared.lane.lock();
        assert!(lane
            .iter()
            .any(|t| t.payload == TaskPayload::Inline(vec![9u8; 32])));
        drop(lane);
        sys.shutdown().unwrap();
    }

    /// A dead thief that had already *fetched* its lazy payload: the
    /// bytes are gone from the store, so the origin rebuilds the task
    /// from the retained args — same id, same bytes, inline again.
    #[test]
    fn fetched_payload_rebuilds_from_retained_args() {
        let sys = task_system(1);
        let topo = StealTopology::uniform(0, &[0, 1]);
        let pool = StealPool::new(
            Arc::clone(&sys),
            &topo,
            StealConfig {
                lazy_threshold: 8,
                ..StealConfig::default()
            },
        );
        pool.register("t/echo", |a| Ok(a.to_vec())).unwrap();
        let id = pool.spawn("t/echo", vec![5u8; 64]).unwrap();
        pool.spawn("t/echo", vec![1u8; 64]).unwrap();
        let stolen =
            decode_tasks(&pool.shared.take_batch(1, 1, 32 * 1024).unwrap()).unwrap();
        assert_eq!(stolen[0].id, id, "oldest first");
        // The thief fetches the payload… then dies.
        assert_eq!(pool.shared.store.take(id).unwrap(), vec![5u8; 64]);
        pool.note_peer_lost(1);
        let lane = pool.shared.lane.lock();
        assert!(lane
            .iter()
            .any(|t| t.id == id && t.payload == TaskPayload::Inline(vec![5u8; 64])));
        drop(lane);
        assert_eq!(pool.recovered(), 1);
        sys.shutdown().unwrap();
    }

    /// A payload-lost report re-spawns the task from retained args
    /// under the same id (pending is not double-counted); once the
    /// re-run completes, a zombie result for the same id is discarded.
    #[test]
    fn payload_lost_report_respawns_and_zombie_is_discarded() {
        let sys = task_system(1);
        let topo = StealTopology::uniform(0, &[0, 1]);
        let pool = StealPool::new(Arc::clone(&sys), &topo, StealConfig::default());
        pool.register("t/echo", |a| Ok(a.to_vec())).unwrap();
        let id = pool.spawn("t/echo", vec![3u8; 16]).unwrap();
        let _ = pool.shared.take_batch(1, 1, 32 * 1024).unwrap();
        assert_eq!(pool.lane_len(), 0, "task is away with the thief");
        pool.shared
            .fulfill(id, 2, Err(format!("{PAYLOAD_LOST} test")));
        assert_eq!(pool.lane_len(), 1, "re-spawned from retained args");
        assert_eq!(pool.pending(), 1, "still counted exactly once");
        assert_eq!(pool.recovered(), 1);
        pool.shared.fulfill(id, 0, Ok(vec![1]));
        assert_eq!(pool.pending(), 0);
        pool.shared.fulfill(id, 2, Ok(vec![1])); // the zombie resurfaces
        assert_eq!(pool.sched_stats().completions_discarded, 1);
        assert_eq!(pool.take_result(id).unwrap(), Some(vec![1]));
        sys.shutdown().unwrap();
    }

    #[test]
    fn spawn_requires_registration() {
        let sys = task_system(1);
        let topo = StealTopology::uniform(0, &[0]);
        let pool = StealPool::new(Arc::clone(&sys), &topo, StealConfig::default());
        assert!(pool.spawn("t/missing", vec![]).is_err());
        pool.register("t/x", |_| Ok(vec![])).unwrap();
        let err = pool.register("t/x", |_| Ok(vec![])).unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err}");
        sys.shutdown().unwrap();
    }

    /// The tentpole end to end, mesh-only (no deployment layer): a
    /// 4-instance world where EVERY task is seeded on instance 0 with a
    /// 96-byte payload (over the lazy threshold). Stealing must drain
    /// the imbalance with zero lost or duplicated tasks, results
    /// splitmix-verified, payload bytes moving lazily.
    #[test]
    fn imbalanced_world_drains_by_stealing() {
        let n = 4u32;
        let tasks = 48u64;
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let done = Arc::new(AtomicBool::new(false));
        let ranks: Vec<u32> = (0..n).collect();
        let mut joins = Vec::new();
        for me in 0..n {
            let cmm = Arc::clone(&cmm);
            let done = Arc::clone(&done);
            let ranks = ranks.clone();
            joins.push(std::thread::spawn(move || -> Result<SchedStats> {
                let mut mesh =
                    RpcMesh::build(&cmm, 0xE1, me, &ranks, 4096, alloc)?;
                let sys = task_system(2);
                let topo = StealTopology::uniform(me, &ranks);
                let pool = StealPool::new(Arc::clone(&sys), &topo, StealConfig::default());
                pool.register("t/value", |args| {
                    // 8-byte index + 88 bytes of index-derived filler the
                    // body verifies, so payload corruption cannot hide.
                    let x = u64::from_le_bytes(args[0..8].try_into().unwrap());
                    for (j, &b) in args[8..].iter().enumerate() {
                        assert_eq!(b, (x as u8).wrapping_add(j as u8));
                    }
                    Ok(crate::apps::taskfarm::task_value(x).to_le_bytes().to_vec())
                })?;
                pool.install(&mut mesh.server)?;
                if me == 0 {
                    let mut ids = Vec::new();
                    for i in 0..tasks {
                        let mut args = i.to_le_bytes().to_vec();
                        args.extend((0..88).map(|j| (i as u8).wrapping_add(j as u8)));
                        ids.push((i, pool.spawn("t/value", args)?));
                    }
                    pool.drive_until_drained(&mut mesh)?;
                    for (i, id) in ids {
                        let got = pool.take_result(id)?.expect("task completed");
                        assert_eq!(
                            u64::from_le_bytes(got.try_into().unwrap()),
                            crate::apps::taskfarm::task_value(i),
                            "task {i} corrupted"
                        );
                    }
                    done.store(true, Ordering::Release);
                } else {
                    pool.drive_while(&mut mesh, || !done.load(Ordering::Acquire))?;
                }
                let stats = pool.sched_stats();
                sys.shutdown()?;
                Ok(stats)
            }));
        }
        let stats: Vec<SchedStats> =
            joins.into_iter().map(|j| j.join().unwrap().unwrap()).collect();
        let root = &stats[0];
        // Every task completed exactly once: take_result verified none
        // were lost, and a crash-free run must discard no zombies.
        let discarded: u64 = stats.iter().map(|s| s.completions_discarded).sum();
        assert_eq!(discarded, 0, "no duplicates in a crash-free run");
        let migrated_out: u64 = stats.iter().map(|s| s.tasks_migrated_out).sum();
        let migrated_in: u64 = stats.iter().map(|s| s.tasks_migrated_in).sum();
        assert_eq!(migrated_in, migrated_out, "no task lost in flight");
        assert!(
            root.tasks_migrated_out > 0,
            "an all-on-root imbalance must trigger stealing: {root:?}"
        );
        let lazy: u64 = stats.iter().map(|s| s.lazy_payload_bytes).sum();
        assert!(lazy > 0, "96-byte payloads must move lazily: {stats:?}");
        let attempts: u64 = stats.iter().map(|s| s.remote_steal_attempts).sum();
        let successes: u64 = stats.iter().map(|s| s.remote_steals).sum();
        assert!(attempts >= successes);
        assert!(successes > 0);
    }
}
