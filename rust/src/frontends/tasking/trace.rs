//! OVNI-analogue instrumentation: per-worker execution traces collected
//! regardless of the computing backend, exportable as JSON and renderable
//! as ASCII timelines (our Paraver stand-in for Figs. 9/10).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// What a trace interval represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Meaningful task work.
    Run,
    /// Scheduling overhead / idle gap (rendered as empty space).
    Idle,
}

/// One closed interval on a worker's timeline.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Worker the interval was recorded on.
    pub worker: usize,
    /// Work vs idle classification.
    pub kind: EventKind,
    /// Task label (free-form, set by the spawner).
    pub label: String,
    /// Interval start, nanoseconds since trace creation.
    pub start_ns: u64,
    /// Interval end, nanoseconds since trace creation.
    pub end_ns: u64,
}

/// Thread-safe trace collector.
pub struct Trace {
    epoch: Instant,
    events: Mutex<Vec<TraceEvent>>,
    enabled: bool,
}

impl Trace {
    /// Create a collector; a disabled trace records nothing (zero cost).
    pub fn new(enabled: bool) -> Self {
        Self {
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
            enabled,
        }
    }

    /// Nanoseconds since trace start.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record a closed interval.
    pub fn record(&self, worker: usize, kind: EventKind, label: &str, start_ns: u64) {
        if !self.enabled {
            return;
        }
        let end_ns = self.now_ns();
        self.events.lock().unwrap().push(TraceEvent {
            worker,
            kind,
            label: label.to_string(),
            start_ns,
            end_ns,
        });
    }

    /// Snapshot of all recorded events.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Total busy (Run) nanoseconds per worker.
    pub fn busy_ns_per_worker(&self, n_workers: usize) -> Vec<u64> {
        let mut busy = vec![0u64; n_workers];
        for e in self.events.lock().unwrap().iter() {
            if e.kind == EventKind::Run && e.worker < n_workers {
                busy[e.worker] += e.end_ns - e.start_ns;
            }
        }
        busy
    }

    /// Export as a JSON array (loadable by external analysis tools — the
    /// paper's "can be loaded into any performance analysis tool").
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.events
                .lock()
                .unwrap()
                .iter()
                .map(|e| {
                    Json::obj([
                        ("worker", e.worker.into()),
                        (
                            "kind",
                            match e.kind {
                                EventKind::Run => "run",
                                EventKind::Idle => "idle",
                            }
                            .into(),
                        ),
                        ("label", e.label.as_str().into()),
                        ("start_ns", e.start_ns.into()),
                        ("end_ns", e.end_ns.into()),
                    ])
                })
                .collect(),
        )
    }

    /// Render an ASCII timeline (one row per worker, `width` columns;
    /// '#' = work, '.' = gap) — the Fig. 9/10 visual.
    pub fn render_ascii(&self, n_workers: usize, width: usize) -> String {
        let events = self.events.lock().unwrap();
        let t_end = events.iter().map(|e| e.end_ns).max().unwrap_or(1).max(1);
        let mut rows = vec![vec!['.'; width]; n_workers];
        for e in events.iter() {
            if e.kind != EventKind::Run || e.worker >= n_workers {
                continue;
            }
            let c0 = (e.start_ns as u128 * width as u128 / t_end as u128) as usize;
            let c1 = (e.end_ns as u128 * width as u128 / t_end as u128) as usize;
            for c in c0..=c1.min(width - 1) {
                rows[e.worker][c] = '#';
            }
        }
        let mut out = String::new();
        for (w, row) in rows.iter().enumerate() {
            out.push_str(&format!("w{w:02} |"));
            out.extend(row.iter());
            out.push_str("|\n");
        }
        out.push_str(&format!(
            "     total {:.3} ms, {} events\n",
            t_end as f64 / 1e6,
            events.len()
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let t = Trace::new(true);
        let s0 = t.now_ns();
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.record(0, EventKind::Run, "task-a", s0);
        let s1 = t.now_ns();
        t.record(1, EventKind::Idle, "gap", s1);
        let events = t.events();
        assert_eq!(events.len(), 2);
        let busy = t.busy_ns_per_worker(2);
        assert!(busy[0] >= 2_000_000);
        assert_eq!(busy[1], 0, "idle intervals are not busy time");
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let t = Trace::new(false);
        t.record(0, EventKind::Run, "x", 0);
        assert!(t.events().is_empty());
    }

    #[test]
    fn ascii_render_shape() {
        let t = Trace::new(true);
        let s = t.now_ns();
        std::thread::sleep(std::time::Duration::from_millis(1));
        t.record(0, EventKind::Run, "t", s);
        let art = t.render_ascii(2, 40);
        let lines: Vec<&str> = art.lines().collect();
        assert_eq!(lines.len(), 3); // 2 workers + summary
        assert!(lines[0].starts_with("w00 |"));
        assert!(lines[0].contains('#'));
        assert!(!lines[1].contains('#'));
    }

    #[test]
    fn json_export_parses() {
        let t = Trace::new(true);
        let s = t.now_ns();
        t.record(3, EventKind::Run, "k", s);
        let text = t.to_json().to_string_compact();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.at(0).get("worker").as_usize(), Some(3));
        assert_eq!(v.at(0).get("kind").as_str(), Some("run"));
    }
}
