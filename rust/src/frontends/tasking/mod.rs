//! Tasking frontend (paper §4.3): building blocks for task-based runtime
//! systems — stateful tasks with state-change callbacks, pull-scheduled
//! worker objects, and an OVNI-style execution tracer.
//!
//! Two execution engines reproduce the paper's Test Case 3/4 variants:
//!
//! - **coro** (Pthreads workers + Boost-like fibers): workers pull tasks
//!   from a shared ready queue and drive them with user-level
//!   suspend/resume; a task waiting on children parks *without* occupying
//!   its worker.
//! - **nosv** (thread-per-task, system-wide scheduler): every task gets a
//!   kernel thread admitted through a global lock; waiting on children
//!   blocks the kernel thread (releasing its concurrency slot), and
//!   completion is eagerly polled.
//!
//! The same application code (a body receiving a [`TaskCtx`]) runs on
//! both — the Fibonacci and Jacobi apps are written once.

pub mod system;
pub mod trace;

pub use system::{TaskCtx, TaskSystem, TaskSystemKind};
pub use trace::{EventKind, Trace, TraceEvent};
