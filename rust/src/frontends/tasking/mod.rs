//! Tasking frontend (paper §4.3): building blocks for task-based runtime
//! systems — stateful tasks with state-change callbacks, pull-scheduled
//! worker objects, and an OVNI-style execution tracer.
//!
//! The frontend is written purely against the abstract compute API: it
//! accepts **any** [`crate::core::compute::ComputeManager`] trait object
//! and negotiates its scheduling engine from the manager's capabilities
//! instead of naming concrete backends:
//!
//! - A manager whose execution states *support suspension* (fiber-class,
//!   e.g. the `coro` plugin) gets the parking scheduler: workers pull
//!   tasks from a shared ready queue and drive them with user-level
//!   `resume()`; a task waiting on children parks *without* occupying
//!   its worker.
//! - A run-to-completion manager (e.g. the `threads` or `nosv` plugins)
//!   gets the blocking scheduler: tasks are admitted into concurrency
//!   slots and waiting on children blocks the kernel thread (releasing
//!   its slot).
//!
//! The paper's Test Case 3/4 engine comparison is thus a pure plugin
//! swap; the same application code (a body receiving a [`TaskCtx`]) runs
//! on every compute backend — the Fibonacci and Jacobi apps are written
//! once.

pub mod system;
pub mod trace;

pub use system::{TaskCtx, TaskSystem};
pub use trace::{EventKind, Trace, TraceEvent};
