//! Tasking frontend (paper §4.3): building blocks for task-based runtime
//! systems — stateful tasks with DAG dependencies, work-stealing worker
//! objects, and an OVNI-style execution tracer.
//!
//! The scheduler is built around **per-worker work-stealing deques**
//! (owner pushes/pops at the bottom, topology-aware thieves steal from
//! the top), with the global queue demoted to an injection/overflow lane
//! and idle workers parking through [`crate::util::backoff`]. Tasks form
//! explicit DAGs beyond the parent/child tree: [`TaskCtx::spawn_after`]
//! gates on completed tasks, [`TaskCtx::spawn_dataflow`] on produced
//! data keys. See DESIGN.md §5 for the deque discipline, steal order and
//! parking protocol, and docs/ARCHITECTURE.md for the lock inventory.
//! The [`steal`] module lifts the same escalate-then-park ladder across
//! instances: an instance whose workers run dry issues pull-based steal
//! RPCs over the deployment mesh, victims ordered by topology, task
//! payloads moving lazily (DESIGN.md §8).
//!
//! The frontend is written purely against the abstract compute API: it
//! accepts **any** [`crate::core::compute::ComputeManager`] trait object
//! and negotiates its scheduling engine from the manager's capabilities
//! instead of naming concrete backends:
//!
//! - A manager whose execution states *support suspension* (fiber-class,
//!   e.g. the `coro` plugin) gets the parking engine: workers drive
//!   stolen tasks with user-level `resume()`; a task waiting on children
//!   parks *without* occupying its worker.
//! - A run-to-completion manager (e.g. the `threads` or `nosv` plugins)
//!   gets the blocking engine: each worker executes tasks through a
//!   reusable processing unit, and a task blocking on children releases
//!   its worker (the unit hosting it is retired and reclaimed later).
//!
//! The paper's Test Case 3/4 engine comparison is thus a pure plugin
//! swap; the same application code (a body receiving a [`TaskCtx`]) runs
//! on every compute backend — the Fibonacci and Jacobi apps are written
//! once.
#![warn(missing_docs)]

mod deque;
pub mod steal;
pub mod system;
pub mod trace;

pub use steal::{
    DescTask, StealConfig, StealPool, StealTopology, TaskPayload, VictimPolicy,
};
pub use system::{
    SchedConfig, SchedPolicy, SchedStats, TaskCtx, TaskHandle, TaskSystem,
};
pub use trace::{EventKind, Trace, TraceEvent};
