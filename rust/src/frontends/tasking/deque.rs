//! Scheduler data structures: per-worker work-stealing deques, the
//! counted global injection lane, per-worker parkers, and the atomic
//! counter block behind [`super::system::SchedStats`].
//!
//! The deque discipline (DESIGN.md §5): the owning worker pushes and pops
//! at the *bottom* (LIFO — depth-first execution, hot caches), thieves
//! steal from the *top* (FIFO — they take the oldest, coarsest task).
//! Each deque is lightly locked (one short-critical-section mutex per
//! worker), with an atomic length word so thieves and parking workers can
//! probe emptiness without ever touching a victim's lock. The only
//! *global* mutex in the scheduler is the injection lane's, and every
//! acquisition of it is counted so tests can assert the steady-state
//! spawn→run→complete path never takes it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Condvar;
use std::time::Duration;

use crate::util::witness::{classes, Lock};

/// A per-worker double-ended work queue.
///
/// Lightly locked rather than lock-free: the mutex is per-worker (never
/// global), the critical sections are a single `VecDeque` operation, and
/// the `len` word lets every other thread probe emptiness lock-free.
/// `len` is maintained with `SeqCst` stores *inside* the critical section
/// so the parking re-check in `next_runnable` cannot miss a concurrent
/// push (see the parking protocol note in DESIGN.md §5).
pub(super) struct WorkDeque<T> {
    len: AtomicUsize,
    items: Lock<VecDeque<T>>,
}

impl<T> WorkDeque<T> {
    pub(super) fn new() -> Self {
        Self {
            len: AtomicUsize::new(0),
            items: Lock::new(&classes::TASKING_DEQUE, VecDeque::new()),
        }
    }

    /// Lock-free emptiness/backlog probe (may be momentarily stale).
    pub(super) fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    /// Owner-side push at the bottom.
    pub(super) fn push_bottom(&self, item: T) {
        let mut q = self.items.lock();
        q.push_back(item);
        self.len.store(q.len(), Ordering::SeqCst);
    }

    /// Owner-side pop at the bottom (LIFO).
    pub(super) fn pop_bottom(&self) -> Option<T> {
        if self.len() == 0 {
            return None;
        }
        let mut q = self.items.lock();
        let item = q.pop_back();
        self.len.store(q.len(), Ordering::SeqCst);
        item
    }

    /// Thief-side steal from the top (FIFO). Probes the atomic length
    /// first so scanning an empty victim costs one atomic load, not a
    /// lock acquisition on the victim's hot path.
    pub(super) fn steal_top(&self) -> Option<T> {
        if self.len() == 0 {
            return None;
        }
        let mut q = self.items.lock();
        let item = q.pop_front();
        self.len.store(q.len(), Ordering::SeqCst);
        item
    }
}

/// The global injection/overflow lane: external submissions
/// (`TaskSystem::submit` / `run`) and the `GlobalQueue` compatibility
/// policy land here. Every mutex acquisition is counted — this is the
/// lock-count instrument behind the "no global scheduler mutex in steady
/// state" acceptance test.
pub(super) struct Injector<T> {
    len: AtomicUsize,
    locks: AtomicU64,
    items: Lock<VecDeque<T>>,
}

impl<T> Injector<T> {
    pub(super) fn new() -> Self {
        Self {
            len: AtomicUsize::new(0),
            locks: AtomicU64::new(0),
            items: Lock::new(&classes::TASKING_INJECTOR, VecDeque::new()),
        }
    }

    /// Lock-free backlog probe.
    pub(super) fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    /// Total mutex acquisitions so far (push + non-empty pop).
    pub(super) fn lock_count(&self) -> u64 {
        // relaxed-ok: telemetry counter; no data is published through this atomic
        self.locks.load(Ordering::Relaxed)
    }

    pub(super) fn push(&self, item: T) {
        // relaxed-ok: telemetry counter; no data is published through this atomic
        self.locks.fetch_add(1, Ordering::Relaxed);
        let mut q = self.items.lock();
        q.push_back(item);
        self.len.store(q.len(), Ordering::SeqCst);
    }

    /// FIFO pop. The empty case is decided by the atomic probe alone, so
    /// idle workers scanning an empty lane never acquire the global lock.
    pub(super) fn pop(&self) -> Option<T> {
        if self.len() == 0 {
            return None;
        }
        // relaxed-ok: telemetry counter; no data is published through this atomic
        self.locks.fetch_add(1, Ordering::Relaxed);
        let mut q = self.items.lock();
        let item = q.pop_front();
        self.len.store(q.len(), Ordering::SeqCst);
        item
    }
}

/// Per-worker parker: a one-permit binary semaphore over (mutex, condvar).
///
/// Producers `unpark` a specific worker; a permit stored before the
/// worker parks makes the next `park` return immediately, so the
/// store-permit/park race is benign. Parks additionally time out (a few
/// milliseconds) as a belt-and-braces bound: a theoretically missed wake
/// degrades to one re-scan of the queues, never to a hang.
pub(super) struct Parker {
    permit: Lock<bool>,
    cv: Condvar,
}

/// Upper bound on one park interval; a missed wake costs at most this.
/// Purely a backstop — every real wake path (push, shutdown) unparks
/// explicitly — so it is sized for negligible idle churn (one queue
/// re-scan per interval per idle worker), not for latency.
const PARK_TIMEOUT: Duration = Duration::from_millis(50);

impl Parker {
    pub(super) fn new() -> Self {
        Self {
            permit: Lock::new(&classes::TASKING_PARKER, false),
            cv: Condvar::new(),
        }
    }

    /// Block until unparked (or the safety timeout elapses), consuming
    /// the permit if one is present.
    pub(super) fn park(&self) {
        let mut permit = self.permit.lock();
        if !*permit {
            let (guard, _timeout) = permit.wait_timeout(&self.cv, PARK_TIMEOUT);
            permit = guard;
        }
        *permit = false;
    }

    /// Store a permit and wake the parked worker, if any.
    pub(super) fn unpark(&self) {
        let mut permit = self.permit.lock();
        *permit = true;
        self.cv.notify_one();
    }
}

/// Atomic scheduler counters (snapshotted into
/// [`super::system::SchedStats`]).
#[derive(Default)]
pub(super) struct SchedCounters {
    /// Pushes onto a worker-local deque (the steady-state spawn path).
    pub(super) local_pushes: AtomicU64,
    /// Pushes onto the global injection lane (external submits; every
    /// spawn under the `GlobalQueue` policy).
    pub(super) injection_pushes: AtomicU64,
    /// Successful steals from another worker's deque.
    pub(super) steals: AtomicU64,
    /// Full victim-scan rounds that found nothing to steal.
    pub(super) steal_failures: AtomicU64,
    /// Times a worker parked after backoff escalated past spinning.
    pub(super) parks: AtomicU64,
    /// Times a producer woke a parked worker.
    pub(super) wakes: AtomicU64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn deque_lifo_bottom_fifo_top() {
        let d = WorkDeque::new();
        d.push_bottom(1);
        d.push_bottom(2);
        d.push_bottom(3);
        assert_eq!(d.len(), 3);
        // Owner pops newest; thief steals oldest.
        assert_eq!(d.pop_bottom(), Some(3));
        assert_eq!(d.steal_top(), Some(1));
        assert_eq!(d.pop_bottom(), Some(2));
        assert_eq!(d.pop_bottom(), None);
        assert_eq!(d.steal_top(), None);
        assert_eq!(d.len(), 0);
    }

    #[test]
    fn injector_counts_locks_and_skips_empty() {
        let i = Injector::new();
        let base = i.lock_count();
        // Empty pops are decided by the atomic probe: no lock taken.
        assert_eq!(i.pop(), None::<u32>);
        assert_eq!(i.lock_count(), base);
        i.push(7);
        assert_eq!(i.lock_count(), base + 1);
        assert_eq!(i.pop(), Some(7));
        assert_eq!(i.lock_count(), base + 2);
        assert_eq!(i.len(), 0);
    }

    #[test]
    fn parker_permit_prevents_lost_wakeup() {
        let p = Arc::new(Parker::new());
        // Unpark before park: the stored permit makes park return at once
        // (well under the 50 ms timeout backstop).
        p.unpark();
        let t0 = std::time::Instant::now();
        p.park();
        assert!(t0.elapsed() < Duration::from_millis(40));
        // Cross-thread wake.
        let p2 = Arc::clone(&p);
        let h = std::thread::spawn(move || p2.park());
        std::thread::sleep(Duration::from_millis(1));
        p.unpark();
        h.join().unwrap();
    }

    #[test]
    fn concurrent_steal_storm_loses_nothing() {
        // 1 owner pushing, 3 thieves stealing: every item surfaces
        // exactly once across pop/steal.
        let d = Arc::new(WorkDeque::new());
        // Miri runs the same interleaving logic at a tractable size.
        let total: u64 = if cfg!(miri) { 300 } else { 10_000 };
        let seen = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..3 {
            let d = Arc::clone(&d);
            let seen = Arc::clone(&seen);
            handles.push(std::thread::spawn(move || {
                let mut sum = 0u64;
                while seen.load(Ordering::SeqCst) < total {
                    if let Some(v) = d.steal_top() {
                        sum += v;
                        seen.fetch_add(1, Ordering::SeqCst);
                    } else {
                        std::thread::yield_now();
                    }
                }
                sum
            }));
        }
        let mut owner_sum = 0u64;
        for v in 1..=total {
            d.push_bottom(v);
            if let Some(x) = d.pop_bottom() {
                owner_sum += x;
                seen.fetch_add(1, Ordering::SeqCst);
            }
        }
        while seen.load(Ordering::SeqCst) < total {
            if let Some(x) = d.pop_bottom() {
                owner_sum += x;
                seen.fetch_add(1, Ordering::SeqCst);
            } else {
                std::thread::yield_now();
            }
        }
        let thief_sum: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(owner_sum + thief_sum, total * (total + 1) / 2);
    }
}
