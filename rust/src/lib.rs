//! # HiCR — an abstract model for distributed heterogeneous programming
//!
//! This crate reproduces the HiCR paper (CS.DC 2025) as a Rust *Runtime
//! Support Layer*: a minimal set of abstract operations — hardware topology
//! discovery, kernel execution, memory management, communication, and
//! instance management — behind which plugin *backends* hide every
//! technology-specific detail. Applications written against the abstract
//! managers in [`core`] run unchanged on any combination of backends.
//!
//! A guided tour of the layering — the module map, the tag-namespace
//! registry shared by the frontends, and the lock inventory ("which lock
//! protects what") for the threads backend and the tasking scheduler —
//! lives in `docs/ARCHITECTURE.md` at the repository root, with the
//! design rationale in `DESIGN.md` and the measured trajectory in
//! `EXPERIMENTS.md`.
//!
//! Layout mirrors the paper's architecture (Fig. 3):
//!
//! - [`core`] — the model: five manager traits plus the stateless
//!   (Topology/Device/MemorySpace/ComputeResource/ExecutionUnit) and
//!   stateful (Instance/ProcessingUnit/ExecutionState/memory slots)
//!   component families, and the **plugin subsystem**
//!   ([`core::plugin`]): backend descriptors with capability bitsets, a
//!   registry, and a [`RuntimeBuilder`] resolving full manager sets from
//!   backend *names* or capability requirements — apps never touch a
//!   concrete backend type.
//! - [`backends`] — built-in plugins (Table 1): host topology & memory
//!   (HWLoc-analogue), threads (Pthreads), fibers (Boost.Context),
//!   thread-per-task (nOS-V), distributed one-sided comms (MPI / LPF
//!   analogues over a socket substrate), and an XLA/PJRT accelerator
//!   backend executing AOT-compiled Pallas/JAX kernels. All seven are
//!   registered in [`backends::registry`]; the Table 1 coverage matrix
//!   is a derived view over it.
//! - [`frontends`] — ready-to-use libraries built *only* on the core API:
//!   Channels (SPSC/MPSC), DataObject, RPC (any-to-any mesh), Deployment
//!   (the Fig. 7 idiom), and Tasking.
//! - [`netsim`] — the distributed substrate: instance launcher/rendezvous,
//!   framed one-sided wire protocol, and calibrated interconnect cost
//!   models (the sandbox has no Infiniband; see DESIGN.md §2).
//! - [`runtime`] — the PJRT bridge loading `artifacts/*.hlo.txt`.
//! - [`apps`] — the paper's four test cases written purely against the
//!   abstract API.

pub mod apps;
pub mod backends;
pub mod core;
pub mod frontends;
pub mod netsim;
pub mod runtime;
pub mod util;

/// Unit-test-only instrumentation: a System-allocator wrapper counting
/// heap allocations *per thread*, so steady-state datapath tests (e.g.
/// the channel push path) can assert a true zero-allocation window
/// without interference from concurrently running tests.
#[cfg(test)]
mod test_alloc {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    // Const-initialized Cell<u64> has no destructor, so accessing it from
    // inside the allocator (even during thread teardown) cannot recurse
    // or abort.
    std::thread_local! {
        static THREAD_HEAP_ALLOCS: Cell<u64> = const { Cell::new(0) };
    }

    /// Heap allocations performed by the calling thread so far.
    pub fn thread_heap_allocations() -> u64 {
        THREAD_HEAP_ALLOCS.with(|c| c.get())
    }

    struct CountingAlloc;

    // SAFETY: pure pass-through to the System allocator; the only extra
    // work is bumping a const-initialized thread-local Cell, which never
    // allocates or unwinds, so GlobalAlloc's contract is System's own.
    unsafe impl GlobalAlloc for CountingAlloc {
        // SAFETY: delegates to System.alloc under the same layout.
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            THREAD_HEAP_ALLOCS.with(|c| c.set(c.get() + 1));
            System.alloc(layout)
        }

        // SAFETY: delegates to System.dealloc under the same layout.
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        // SAFETY: delegates to System.realloc under the same layout.
        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            THREAD_HEAP_ALLOCS.with(|c| c.set(c.get() + 1));
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static COUNTING_ALLOC: CountingAlloc = CountingAlloc;
}

pub use crate::core::communication::{
    CommunicationManager, CompletionHandle, DataEndpoint, GlobalMemorySlot,
};
pub use crate::core::compute::{
    ComputeManager, ExecStatus, ExecutionState, ExecutionUnit, ProcessingUnit,
};
pub use crate::core::error::{HicrError, Result};
pub use crate::core::ids::{
    ComputeResourceId, DeviceId, InstanceId, Key, MemorySpaceId, Tag,
};
pub use crate::core::instance::{Instance, InstanceManager, InstanceTemplate};
pub use crate::core::memory::{LocalMemorySlot, MemoryManager};
pub use crate::core::plugin::{
    BackendCoverage, BackendPlugin, Capabilities, ManagerSet, PluginContext, Registry,
    RuntimeBuilder,
};
pub use crate::core::topology::{
    ComputeResource, Device, DeviceKind, MemorySpace, MemorySpaceKind, Topology,
    TopologyManager,
};
