//! `hicr` — the leader entrypoint and CLI.
//!
//! Subcommands:
//! - `topology`            print the merged local topology (hostmem + xlacomp)
//! - `backends`            print the backend coverage matrix (Table 1)
//! - `launch --np N -- <app> [args]`
//!                         start the hub, spawn N instance processes, run
//!                         the named distributed app in each
//! - `worker`              internal: instance-process entrypoint (spawned
//!                         by `launch`; configured via HICR_* env vars)
//!
//! Distributed apps available under `launch`: `pingpong` (Test Case 1
//! measured mode), `jacobi` (Fig. 11 halo-exchange solver), `spawntest`
//! (Fig. 7 runtime instance creation).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use hicr::apps::{jacobi, pingpong};
use hicr::backends::hostmem::HostTopologyManager;
use hicr::backends::mpisim::instance::{ENV_HUB, ENV_RANK, ENV_WORLD};
use hicr::backends::mpisim::MpiInstanceManager;
use hicr::backends::xlacomp::XlaTopologyManager;
use hicr::core::instance::{ensure_instances, InstanceManager, InstanceTemplate};
use hicr::core::topology::{TopologyManager, TopologyRequirements};
use hicr::frontends::tasking::{TaskSystem, TaskSystemKind};
use hicr::netsim::hub::Hub;
use hicr::runtime::XlaRuntime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    match args.get(1).map(String::as_str) {
        Some("topology") => cmd_topology(),
        Some("backends") => cmd_backends(),
        Some("launch") => cmd_launch(&args[2..]),
        Some("worker") => cmd_worker(),
        _ => {
            eprintln!(
                "usage: hicr <topology|backends|launch --np N -- <app> [args]>\n\
                 apps: pingpong | jacobi [n iters] | spawntest"
            );
            Ok(())
        }
    }
}

fn cmd_topology() -> Result<()> {
    let mut topo = HostTopologyManager::new().query_topology()?;
    match XlaRuntime::cpu() {
        Ok(rt) => {
            let accel = XlaTopologyManager::new(Arc::new(rt)).query_topology()?;
            topo.merge(accel).ok();
        }
        Err(e) => eprintln!("(xlacomp unavailable: {e})"),
    }
    for d in &topo.devices {
        println!("device {} [{:?}] '{}'", d.id, d.kind, d.name);
        for m in &d.memory_spaces {
            println!(
                "  memory space {} [{:?}] {}  '{}'",
                m.id,
                m.kind,
                hicr::util::stats::fmt_bytes(m.size_bytes),
                m.label
            );
        }
        println!("  compute resources: {}", d.compute_resources.len());
    }
    println!("\nserialized: {} bytes", topo.serialize().len());
    Ok(())
}

fn cmd_backends() -> Result<()> {
    println!(
        "{:<10} {:>9} {:>9} {:>14} {:>7} {:>8}",
        "backend", "topology", "instance", "communication", "memory", "compute"
    );
    for row in hicr::backends::coverage_matrix() {
        let mark = |b: bool| if b { "x" } else { "" };
        println!(
            "{:<10} {:>9} {:>9} {:>14} {:>7} {:>8}",
            row.name,
            mark(row.topology),
            mark(row.instance),
            mark(row.communication),
            mark(row.memory),
            mark(row.compute)
        );
    }
    Ok(())
}

/// `hicr launch --np N -- <app> [args]`
fn cmd_launch(args: &[String]) -> Result<()> {
    let mut np = 2usize;
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--np" => {
                np = args
                    .get(i + 1)
                    .context("--np needs a value")?
                    .parse()
                    .context("bad --np")?;
                i += 1;
            }
            "--" => {
                rest = args[i + 1..].to_vec();
                break;
            }
            other => bail!("unknown launch flag {other}"),
        }
        i += 1;
    }
    if rest.is_empty() {
        bail!("launch requires `-- <app> [args]`");
    }
    let sock = std::env::temp_dir().join(format!("hicr-hub-{}.sock", std::process::id()));
    let exe = std::env::current_exe()?;
    let sock2 = sock.clone();
    let rest2 = rest.clone();
    // Runtime spawns (Fig. 7) reuse the same worker entry.
    let spawn_fn = move |rank: u32, _template: &str| {
        std::process::Command::new(&exe)
            .arg("worker")
            .env(ENV_RANK, rank.to_string())
            .env(ENV_WORLD, "0")
            .env(ENV_HUB, &sock2)
            .env("HICR_APP", rest2.join(" "))
            .spawn()
            .map_err(|e| hicr::HicrError::Instance(format!("spawn rank {rank}: {e}")))?;
        Ok(())
    };
    let hub = Hub::bind(&sock, np, Some(Box::new(spawn_fn)))?;
    let exe = std::env::current_exe()?;
    let mut children = Vec::new();
    for rank in 0..np {
        children.push(
            std::process::Command::new(&exe)
                .arg("worker")
                .env(ENV_RANK, rank.to_string())
                .env(ENV_WORLD, np.to_string())
                .env(ENV_HUB, &sock)
                .env("HICR_APP", rest.join(" "))
                .spawn()
                .with_context(|| format!("spawn rank {rank}"))?,
        );
    }
    let hub_result = hub.run();
    for mut c in children {
        let status = c.wait()?;
        if !status.success() {
            eprintln!("instance exited with {status}");
        }
    }
    hub_result?;
    Ok(())
}

/// Instance-process entrypoint.
fn cmd_worker() -> Result<()> {
    let app = std::env::var("HICR_APP").unwrap_or_default();
    let words: Vec<&str> = app.split_whitespace().collect();
    let im = MpiInstanceManager::from_env().context("worker env")?;
    let me = im.current_instance();
    let endpoint = im.endpoint().clone();
    let result = match words.first().copied() {
        Some("pingpong") => worker_pingpong(&im),
        Some("jacobi") => {
            let n: usize = words.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
            let iters: usize = words.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);
            worker_jacobi(&im, n, iters)
        }
        Some("spawntest") => worker_spawntest(&im),
        other => bail!("unknown app {other:?}"),
    };
    endpoint.bye();
    result.map_err(|e| anyhow::anyhow!("rank {} app error: {e}", me.id))
}

/// Test Case 1, measured mode: rank 0 pings, rank 1 pongs.
fn worker_pingpong(im: &MpiInstanceManager) -> Result<()> {
    use hicr::apps::pingpong::Side;
    let rank = im.current_instance().id.0;
    let cmm: Arc<dyn hicr::CommunicationManager> = Arc::new(
        hicr::backends::lpfsim::communication_manager(im.endpoint().clone()),
    );
    let sizes: Vec<usize> = vec![1, 64, 4096, 65536, 1 << 20];
    let reps = 20;
    for (si, &size) in sizes.iter().enumerate() {
        let tag = 9000 + (si as u64) * 4;
        let side = if rank == 0 { Side::Pinger } else { Side::Ponger };
        let (mut p, mut c) = pingpong::build_channels(Arc::clone(&cmm), tag, size, side)?;
        if rank == 0 {
            let times = pingpong::run_pinger(&mut p, &mut c, size, reps)?;
            let point = pingpong::goodput_from_rtts(size as u64, &times);
            println!(
                "pingpong size={size} goodput={} (+-{})",
                hicr::util::stats::fmt_bps(point.goodput_bps),
                hicr::util::stats::fmt_bps(point.stddev_bps),
            );
        } else {
            pingpong::run_ponger(&mut p, &mut c, size, reps)?;
        }
        im.barrier()?;
    }
    Ok(())
}

/// Fig. 11 worker: distributed Jacobi over the LPF backend.
fn worker_jacobi(im: &MpiInstanceManager, n: usize, iters: usize) -> Result<()> {
    let rank = im.current_instance().id.0;
    let world = im.instances()?.len() as u32;
    let cmm: Arc<dyn hicr::CommunicationManager> = Arc::new(
        hicr::backends::lpfsim::communication_manager(im.endpoint().clone()),
    );
    let sys = TaskSystem::new(TaskSystemKind::Coro, 2, false);
    let run = jacobi::run_distributed(
        &cmm,
        &sys,
        rank,
        world,
        n,
        iters,
        (1, 2, 2),
        jacobi::CommWaitMode::Blocking,
    )?;
    sys.shutdown()?;
    println!(
        "rank {rank}: jacobi n={n} iters={iters} {:.3}s {:.3} GFlop/s checksum={:.6}",
        run.elapsed_s, run.gflops, run.checksum
    );
    im.barrier()?;
    Ok(())
}

/// Fig. 7 demo: root tops up the instance count at runtime.
fn worker_spawntest(im: &MpiInstanceManager) -> Result<()> {
    let desired = 3;
    let template = InstanceTemplate::new(TopologyRequirements::default());
    let created = ensure_instances(im, desired, &template)?;
    if im.is_root() {
        println!(
            "root: created {} instance(s) at runtime; now {} total",
            created.len(),
            im.instances()?.len()
        );
    }
    Ok(())
}
