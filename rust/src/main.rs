//! `hicr` — the leader entrypoint and CLI.
//!
//! Subcommands:
//! - `topology`            print the merged local topology (every
//!                         topology-capable plugin in the registry)
//! - `backends`            print the backend coverage matrix (Table 1,
//!                         derived from the plugin registry)
//! - `run <app> [flags]`   run a single-instance app with backends
//!                         selected *by name*:
//!                         `run fibonacci --compute <threads|coro|nosv>`
//! - `launch --np N [--comm C] [--compute C] -- <app> [args]`
//!                         start the hub, spawn N instance processes, run
//!                         the named distributed app in each
//! - `serve --np N [--requests R] [--window W]`
//!                         sugar for `launch --np N -- serve …`: bring up
//!                         the inference serving tier (root router +
//!                         N−1 continuous-batching workers) and drive it
//!                         with the built-in verifying closed-loop client
//! - `worker`              internal: instance-process entrypoint (spawned
//!                         by `launch`; configured via HICR_* env vars)
//!
//! All wiring goes through `core::plugin::RuntimeBuilder`: no subcommand
//! names a concrete backend type — backends are chosen by CLI name
//! (`--compute coro`) or capability and resolved to `Arc<dyn …Manager>`
//! trait objects.
//!
//! Distributed apps available under `launch`: `pingpong` (Test Case 1
//! measured mode), `jacobi` (the Fig. 11 solver — hdarray-frontend mode
//! by default, hand-rolled `pipeline` mode as the ablation), `stencil`
//! (arbitrary-radius 1-D hdarray sweep, bitwise-verified), `spawntest`
//! (Fig. 7 runtime instance creation), and `taskfarm [total] [tasks]`
//! (the full Fig. 7 deployment: root elastically ensures `total`
//! instances — spawning the difference at runtime when `total` exceeds
//! `--np` — gathers every worker's topology through the built-in
//! `topology` RPC, farms `tasks` verified tasks across the mesh, and
//! shuts the workers down by RPC).

use std::sync::Arc;

use hicr::apps::{fibonacci, jacobi, pingpong};
use hicr::backends::mpisim::instance::{ENV_HUB, ENV_RANK, ENV_WORLD};
use hicr::core::instance::{ensure_instances, InstanceTemplate};
use hicr::core::topology::TopologyRequirements;
use hicr::frontends::tasking::TaskSystem;
use hicr::netsim::endpoint::Endpoint;
use hicr::netsim::hub::Hub;
use hicr::{CommunicationManager, InstanceManager, PluginContext, Registry};

/// Backend selections forwarded from `launch` to every worker process.
const ENV_COMM: &str = "HICR_COMM";
const ENV_COMPUTE: &str = "HICR_COMPUTE";

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn err(msg: impl Into<String>) -> Box<dyn std::error::Error> {
    msg.into().into()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    match args.get(1).map(String::as_str) {
        Some("topology") => cmd_topology(),
        Some("backends") => cmd_backends(),
        Some("run") => cmd_run(&args[2..]),
        Some("launch") => cmd_launch(&args[2..]),
        Some("serve") => cmd_serve(&args[2..]),
        Some("worker") => cmd_worker(),
        _ => {
            eprintln!(
                "usage: hicr <topology|backends|run <app> [flags]|launch --np N \
                 [--comm C] [--compute C] -- <app> [args]|serve --np N \
                 [--requests R] [--window W]>\n\
                 run apps:    fibonacci [--n N] | jacobi [--n N --iters I] | \
                 inference [--images M]   (+ --compute <name> --workers W)\n\
                 launch apps: pingpong | jacobi [n iters hdarray|pipeline] | \
                 stencil [len iters radius block|cyclic] | spawntest | \
                 taskfarm [total] [tasks] [steal|spill] [--chaos kill-one] | \
                 serve [total] [requests] [window]\n\
                 stencil: arbitrary-radius 1-D sweep over the hdarray \
                 frontend; the root bitwise-verifies against the \
                 sequential reference (verified=ok)\n\
                 serve: root runs a sharded request router, every other \
                 instance a continuous-batching inference worker; the root's \
                 closed-loop client verifies each response payload and \
                 reports p50/p99 latency + goodput\n\
                 taskfarm: root ensures `total` instances (default --np; \
                 spawning the difference at runtime), gathers worker \
                 topologies by RPC, farms `tasks` (default 100) verified \
                 tasks across the mesh, then shuts workers down by RPC\n\
                 backends: selected by name from the plugin registry \
                 (`hicr backends` lists them)"
            );
            Ok(())
        }
    }
}

/// Merge the topology of every topology-capable plugin (the paper's
/// combined-manager pattern, Fig. 4/5 — previously hand-wired to two
/// concrete managers, now derived from the registry).
fn cmd_topology() -> Result<()> {
    let registry = hicr::backends::registry();
    let topo = hicr::backends::merged_topology(&registry, &PluginContext::new())?;
    for d in &topo.devices {
        println!("device {} [{:?}] '{}'", d.id, d.kind, d.name);
        for m in &d.memory_spaces {
            println!(
                "  memory space {} [{:?}] {}  '{}'",
                m.id,
                m.kind,
                hicr::util::stats::fmt_bytes(m.size_bytes),
                m.label
            );
        }
        println!("  compute resources: {}", d.compute_resources.len());
    }
    println!("\nserialized: {} bytes", topo.serialize().len());
    Ok(())
}

fn cmd_backends() -> Result<()> {
    println!(
        "{:<10} {:>9} {:>9} {:>14} {:>7} {:>8}",
        "backend", "topology", "instance", "communication", "memory", "compute"
    );
    for row in hicr::backends::coverage_matrix() {
        let mark = |b: bool| if b { "x" } else { "" };
        println!(
            "{:<10} {:>9} {:>9} {:>14} {:>7} {:>8}",
            row.name,
            mark(row.topology),
            mark(row.instance),
            mark(row.communication),
            mark(row.memory),
            mark(row.compute)
        );
    }
    Ok(())
}

/// `hicr run <app> [--compute NAME] [--workers W] [--n N] [--iters I]
/// [--images M]` — single-instance apps with registry-resolved backends.
fn cmd_run(args: &[String]) -> Result<()> {
    let app = args
        .first()
        .ok_or_else(|| err("run requires an app: fibonacci | jacobi | inference"))?
        .clone();
    fn flag_value<'a>(args: &'a [String], i: usize, flag: &str) -> Result<&'a str> {
        args.get(i + 1)
            .map(String::as_str)
            .ok_or_else(|| err(format!("{flag} needs a value")))
    }
    let mut compute = "coro".to_string();
    let mut workers = 4usize;
    let mut n: Option<u64> = None;
    let mut iters = 10usize;
    let mut images = 200usize;
    let mut i = 1;
    while i < args.len() {
        let flag = args[i].as_str();
        match flag {
            "--compute" => {
                compute = flag_value(args, i, flag)?.to_string();
                i += 1;
            }
            "--workers" => {
                workers = flag_value(args, i, flag)?
                    .parse()
                    .map_err(|e| err(format!("bad --workers: {e}")))?;
                i += 1;
            }
            "--n" => {
                n = Some(
                    flag_value(args, i, flag)?
                        .parse()
                        .map_err(|e| err(format!("bad --n: {e}")))?,
                );
                i += 1;
            }
            "--iters" => {
                iters = flag_value(args, i, flag)?
                    .parse()
                    .map_err(|e| err(format!("bad --iters: {e}")))?;
                i += 1;
            }
            "--images" => {
                images = flag_value(args, i, flag)?
                    .parse()
                    .map_err(|e| err(format!("bad --images: {e}")))?;
                i += 1;
            }
            other => return Err(err(format!("unknown run flag {other}"))),
        }
        i += 1;
    }
    let registry = hicr::backends::registry();
    let task_system = |registry: &Registry, workers: usize| -> Result<Arc<TaskSystem>> {
        let cm = registry
            .builder()
            .compute(compute.as_str())
            .build()?
            .compute()?;
        Ok(TaskSystem::new(cm, workers, false))
    };
    match app.as_str() {
        "fibonacci" => {
            let n = n.unwrap_or(16);
            let sys = task_system(&registry, workers)?;
            let run = fibonacci::run(&sys, n)?;
            sys.shutdown()?;
            println!(
                "fibonacci n={n} value={} tasks={} backend={} elapsed={:.3}s",
                run.value,
                run.tasks_executed,
                sys.backend_name(),
                run.elapsed_s
            );
        }
        "jacobi" => {
            let n = n.unwrap_or(32) as usize;
            let sys = task_system(&registry, workers)?;
            let mut grid = jacobi::Grid::new(n);
            let run = jacobi::run_local(&sys, &mut grid, iters, (1, 2, 2))?;
            sys.shutdown()?;
            println!(
                "jacobi n={n} iters={iters} checksum={:.9} backend={} \
                 elapsed={:.3}s gflops={:.3}",
                run.checksum,
                sys.backend_name(),
                run.elapsed_s,
                run.gflops
            );
        }
        "inference" => {
            let bundle =
                hicr::runtime::ArtifactBundle::load(&hicr::runtime::ArtifactBundle::default_dir())
                    .map_err(|e| err(format!("artifacts not built (`make artifacts`): {e}")))?;
            let cm = registry
                .builder()
                .compute(compute.as_str())
                .build()?
                .compute()?;
            let provider = hicr::apps::inference::NativeKernels::new(&bundle, cm)?;
            let report = hicr::apps::inference::evaluate(&provider, &bundle, images)?;
            println!(
                "inference images={} accuracy={:.4} img0_pred={} backend={} \
                 elapsed={:.3}s",
                report.images,
                report.accuracy,
                report.img0_pred,
                report.backend,
                report.elapsed_s
            );
        }
        other => return Err(err(format!("unknown run app {other}"))),
    }
    Ok(())
}

/// `hicr serve --np N [--comm C] [--compute C] [--requests R]
/// [--window W]` — sugar for `launch --np N -- serve N R W`.
fn cmd_serve(args: &[String]) -> Result<()> {
    let mut np = 3usize;
    let mut comm = "lpfsim".to_string();
    let mut compute = "coro".to_string();
    let mut requests = 256u64;
    let mut window = 16usize;
    let mut i = 0;
    while i < args.len() {
        let flag = args[i].as_str();
        let value = |args: &[String], i: usize| -> Result<String> {
            args.get(i + 1)
                .cloned()
                .ok_or_else(|| err(format!("{flag} needs a value")))
        };
        match flag {
            "--np" => np = value(args, i)?.parse().map_err(|e| err(format!("bad --np: {e}")))?,
            "--comm" => comm = value(args, i)?,
            "--compute" => compute = value(args, i)?,
            "--requests" => {
                requests = value(args, i)?
                    .parse()
                    .map_err(|e| err(format!("bad --requests: {e}")))?
            }
            "--window" => {
                window = value(args, i)?
                    .parse()
                    .map_err(|e| err(format!("bad --window: {e}")))?
            }
            other => return Err(err(format!("unknown serve flag {other}"))),
        }
        i += 2;
    }
    if np < 2 {
        return Err(err("serve needs --np >= 2 (one router + >=1 worker)"));
    }
    let launch_args: Vec<String> = [
        "--np",
        &np.to_string(),
        "--comm",
        &comm,
        "--compute",
        &compute,
        "--",
        "serve",
        &np.to_string(),
        &requests.to_string(),
        &window.to_string(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    cmd_launch(&launch_args)
}

/// `hicr launch --np N [--comm C] [--compute C] -- <app> [args]`
fn cmd_launch(args: &[String]) -> Result<()> {
    let mut np = 2usize;
    let mut comm = "lpfsim".to_string();
    let mut compute = "coro".to_string();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--np" => {
                np = args
                    .get(i + 1)
                    .ok_or_else(|| err("--np needs a value"))?
                    .parse()
                    .map_err(|e| err(format!("bad --np: {e}")))?;
                i += 1;
            }
            "--comm" => {
                comm = args
                    .get(i + 1)
                    .ok_or_else(|| err("--comm needs a value"))?
                    .clone();
                i += 1;
            }
            "--compute" => {
                compute = args
                    .get(i + 1)
                    .ok_or_else(|| err("--compute needs a value"))?
                    .clone();
                i += 1;
            }
            "--" => {
                rest = args[i + 1..].to_vec();
                break;
            }
            other => return Err(err(format!("unknown launch flag {other}"))),
        }
        i += 1;
    }
    if rest.is_empty() {
        return Err(err("launch requires `-- <app> [args]`"));
    }
    let sock = std::env::temp_dir().join(format!("hicr-hub-{}.sock", std::process::id()));
    let exe = std::env::current_exe()?;
    let sock2 = sock.clone();
    let rest2 = rest.clone();
    let (comm2, compute2) = (comm.clone(), compute.clone());
    // Runtime spawns (Fig. 7) reuse the same worker entry.
    let spawn_fn = move |rank: u32, _template: &str| {
        std::process::Command::new(&exe)
            .arg("worker")
            .env(ENV_RANK, rank.to_string())
            .env(ENV_WORLD, "0")
            .env(ENV_HUB, &sock2)
            .env(ENV_COMM, &comm2)
            .env(ENV_COMPUTE, &compute2)
            .env("HICR_APP", rest2.join(" "))
            .spawn()
            .map_err(|e| hicr::HicrError::Instance(format!("spawn rank {rank}: {e}")))?;
        Ok(())
    };
    let hub = Hub::bind(&sock, np, Some(Box::new(spawn_fn)))?;
    let exe = std::env::current_exe()?;
    let mut children = Vec::new();
    for rank in 0..np {
        children.push(
            std::process::Command::new(&exe)
                .arg("worker")
                .env(ENV_RANK, rank.to_string())
                .env(ENV_WORLD, np.to_string())
                .env(ENV_HUB, &sock)
                .env(ENV_COMM, &comm)
                .env(ENV_COMPUTE, &compute)
                .env("HICR_APP", rest.join(" "))
                .spawn()
                .map_err(|e| err(format!("spawn rank {rank}: {e}")))?,
        );
    }
    let hub_result = hub.run();
    for mut c in children {
        let status = c.wait()?;
        if !status.success() {
            eprintln!("instance exited with {status}");
        }
    }
    hub_result?;
    Ok(())
}

/// Instance-process entrypoint. The full manager set is resolved through
/// the registry: instance management by name ("mpisim"), communication by
/// the launcher-forwarded `--comm` selection, tasking compute by
/// `--compute` — the worker never touches a concrete backend type.
fn cmd_worker() -> Result<()> {
    let app = std::env::var("HICR_APP").unwrap_or_default();
    let comm = std::env::var(ENV_COMM).unwrap_or_else(|_| "lpfsim".to_string());
    let compute = std::env::var(ENV_COMPUTE).unwrap_or_else(|_| "coro".to_string());
    let words: Vec<&str> = app.split_whitespace().collect();

    // Substrate bootstrap: connect this process to the launcher's hub.
    let rank: u32 = std::env::var(ENV_RANK)
        .map_err(|_| err(format!("{ENV_RANK} not set")))?
        .parse()
        .map_err(|e| err(format!("bad {ENV_RANK}: {e}")))?;
    let hub = std::env::var(ENV_HUB).map_err(|_| err(format!("{ENV_HUB} not set")))?;
    let endpoint = Endpoint::connect(std::path::Path::new(&hub), rank)?;

    let registry = hicr::backends::registry();
    let set = registry
        .builder()
        .with(endpoint.clone())
        .instance("mpisim")
        .communication(comm.as_str())
        .build()?;
    let im = set.instance()?;
    let cmm = set.communication()?;
    let me = im.current_instance();
    let result = match words.first().copied() {
        Some("pingpong") => worker_pingpong(im.as_ref(), &cmm),
        Some("jacobi") => {
            let n: usize = words.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
            let iters: usize = words.get(2).and_then(|s| s.parse().ok()).unwrap_or(20);
            let mode = words.get(3).copied().unwrap_or("hdarray");
            worker_jacobi(im.as_ref(), &cmm, &registry, &compute, n, iters, mode)
        }
        Some("stencil") => {
            let len: usize = words.get(1).and_then(|s| s.parse().ok()).unwrap_or(4096);
            let iters: usize = words.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);
            let radius: usize = words.get(3).and_then(|s| s.parse().ok()).unwrap_or(3);
            let dist = words.get(4).copied().unwrap_or("block");
            worker_stencil(&im, &cmm, &registry, &compute, len, iters, radius, dist)
        }
        Some("spawntest") => worker_spawntest(im.as_ref()),
        Some("taskfarm") => {
            // `--chaos <mode>` may appear anywhere after the app name;
            // strip it before reading the positional words.
            let mut positional: Vec<&str> = Vec::new();
            let mut chaos: Option<&str> = None;
            let mut it = words[1..].iter();
            while let Some(&w) = it.next() {
                if w == "--chaos" {
                    chaos = Some(
                        it.next()
                            .copied()
                            .ok_or_else(|| err("--chaos needs a value"))?,
                    );
                } else {
                    positional.push(w);
                }
            }
            let total: usize = positional
                .first()
                .and_then(|s| s.parse().ok())
                .or_else(|| {
                    std::env::var(ENV_WORLD)
                        .ok()
                        .and_then(|w| w.parse().ok())
                        .filter(|w| *w > 0)
                })
                .unwrap_or(2);
            let tasks: u64 = positional.get(1).and_then(|s| s.parse().ok()).unwrap_or(100);
            let mode = positional.get(2).copied().unwrap_or("steal");
            worker_taskfarm(
                im.as_ref(),
                &cmm,
                &registry,
                &compute,
                total,
                tasks,
                mode,
                chaos,
            )
        }
        Some("serve") => {
            let total: usize = words
                .get(1)
                .and_then(|s| s.parse().ok())
                .or_else(|| {
                    std::env::var(ENV_WORLD)
                        .ok()
                        .and_then(|w| w.parse().ok())
                        .filter(|w| *w > 0)
                })
                .unwrap_or(3);
            let requests: u64 = words.get(2).and_then(|s| s.parse().ok()).unwrap_or(256);
            let window: usize = words.get(3).and_then(|s| s.parse().ok()).unwrap_or(16);
            worker_serve(im.as_ref(), &cmm, &registry, total, requests, window)
        }
        other => Err(err(format!("unknown app {other:?}"))),
    };
    endpoint.bye();
    result.map_err(|e| err(format!("rank {} app error: {e}", me.id)))
}

/// Test Case 1, measured mode: rank 0 pings, rank 1 pongs.
fn worker_pingpong(im: &dyn InstanceManager, cmm: &Arc<dyn CommunicationManager>) -> Result<()> {
    use hicr::apps::pingpong::Side;
    let rank = im.current_instance().id.0;
    let sizes: Vec<usize> = vec![1, 64, 4096, 65536, 1 << 20];
    let reps = 20;
    for (si, &size) in sizes.iter().enumerate() {
        let tag = 9000 + (si as u64) * 4;
        let side = if rank == 0 { Side::Pinger } else { Side::Ponger };
        let (mut p, mut c) = pingpong::build_channels(Arc::clone(cmm), tag, size, side)?;
        if rank == 0 {
            let times = pingpong::run_pinger(&mut p, &mut c, size, reps)?;
            let point = pingpong::goodput_from_rtts(size as u64, &times);
            println!(
                "pingpong size={size} goodput={} (+-{})",
                hicr::util::stats::fmt_bps(point.goodput_bps),
                hicr::util::stats::fmt_bps(point.stddev_bps),
            );
        } else {
            pingpong::run_ponger(&mut p, &mut c, size, reps)?;
        }
        im.barrier()?;
    }
    Ok(())
}

/// Distributed Jacobi worker. The default `hdarray` mode declares a
/// distribution and lets the array frontend derive the halo pipeline;
/// `pipeline` keeps the hand-rolled Fig. 11 halo exchange as the
/// ablation baseline.
#[allow(clippy::too_many_arguments)]
fn worker_jacobi(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    registry: &Registry,
    compute: &str,
    n: usize,
    iters: usize,
    mode: &str,
) -> Result<()> {
    let rank = im.current_instance().id.0;
    let cm = registry.builder().compute(compute).build()?.compute()?;
    let sys = TaskSystem::new(cm, 2, false);
    match mode {
        "hdarray" => {
            let mut ranks: Vec<u32> = im.instances()?.iter().map(|i| i.id.0).collect();
            ranks.sort_unstable();
            let me_pos = ranks
                .iter()
                .position(|&r| r == rank)
                .ok_or_else(|| err(format!("rank {rank} not in the world")))?;
            use hicr::frontends::hdarray::Distribution;
            let checksum = jacobi::run_hdarray(
                Arc::clone(cmm),
                &sys,
                me_pos,
                &ranks,
                Distribution::Block,
                n,
                iters,
            )?;
            sys.shutdown()?;
            if let Some(sum) = checksum {
                println!("jacobi world={} n={n} iters={iters} checksum={sum:.6}", ranks.len());
            }
        }
        "pipeline" => {
            let world = im.instances()?.len() as u32;
            let run = jacobi::run_distributed(
                cmm,
                &sys,
                rank,
                world,
                n,
                iters,
                (1, 2, 2),
                jacobi::CommWaitMode::Blocking,
            )?;
            sys.shutdown()?;
            println!(
                "rank {rank}: jacobi n={n} iters={iters} {:.3}s {:.3} GFlop/s checksum={:.6}",
                run.elapsed_s, run.gflops, run.checksum
            );
        }
        other => return Err(err(format!("unknown jacobi mode '{other}'"))),
    }
    im.barrier()?;
    Ok(())
}

/// Arbitrary-radius stencil worker over the hdarray frontend: the root
/// bitwise-verifies the gathered array against the sequential reference
/// and prints the grep-able `verified=ok` line the CI smoke gates on.
#[allow(clippy::too_many_arguments)]
fn worker_stencil(
    im: &Arc<dyn InstanceManager>,
    cmm: &Arc<dyn CommunicationManager>,
    registry: &Registry,
    compute: &str,
    len: usize,
    iters: usize,
    radius: usize,
    dist: &str,
) -> Result<()> {
    use hicr::apps::stencil;
    use hicr::frontends::hdarray::Distribution;
    let dist = match dist {
        "cyclic" => Distribution::Cyclic,
        _ => Distribution::Block,
    };
    let rank = im.current_instance().id.0;
    let mut ranks: Vec<u32> = im.instances()?.iter().map(|i| i.id.0).collect();
    ranks.sort_unstable();
    let me_pos = ranks
        .iter()
        .position(|&r| r == rank)
        .ok_or_else(|| err(format!("rank {rank} not in the world")))?;
    let cm = registry.builder().compute(compute).build()?.compute()?;
    let sys = TaskSystem::new(cm, 2, false);
    let probe_im = Arc::clone(im);
    let report = stencil::run_distributed(
        Arc::clone(cmm),
        &sys,
        me_pos,
        &ranks,
        dist,
        len,
        iters,
        radius,
        Some(Arc::new(move || probe_im.departed_instances())),
    )?;
    sys.shutdown()?;
    if let Some(r) = report {
        println!(
            "stencil world={} len={} iters={} radius={} dist={dist:?} residual={:.3e} verified={}",
            ranks.len(),
            r.len,
            r.iters,
            r.radius,
            r.residual,
            if r.verified { "ok" } else { "FAIL" }
        );
    }
    im.barrier()?;
    Ok(())
}

/// The full Fig. 7 deployment: elastic ramp-up to `total` instances,
/// worker-topology gathering over the built-in `topology` RPC, and a
/// verified master/worker task farm across the RPC mesh. The default
/// `steal` mode seeds every task on the root and lets idle instances
/// pull work over the mesh (topology-ordered victims, lazy payloads);
/// `spill` mode is the push-only ablation, where the root runs tasks on
/// a local work-stealing `TaskSystem` and pushes the overflow whenever
/// its scheduler backlog saturates. `--chaos kill-one` (steal mode
/// only) injects a worker crash mid-drain; the farm must recover the
/// victim's stolen tasks and still verify every result.
#[allow(clippy::too_many_arguments)]
fn worker_taskfarm(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    registry: &Registry,
    compute: &str,
    total: usize,
    tasks: u64,
    mode: &str,
    chaos: Option<&str>,
) -> Result<()> {
    use hicr::apps::taskfarm::{run_spill, run_steal_chaos, ChaosMode, SpillPolicy};
    use hicr::frontends::tasking::StealConfig;
    let chaos = chaos.map(ChaosMode::parse).transpose()?;
    if chaos.is_some() && mode != "steal" {
        return Err(err(format!(
            "--chaos requires the steal farm (got mode '{mode}')"
        )));
    }
    // Serialize this instance's device tree for the topology RPC; an
    // environment with no discoverable topology still farms (empty tree).
    let topology_json = hicr::backends::merged_topology(registry, &PluginContext::new())
        .map(|t| t.serialize())
        .unwrap_or_else(|_| hicr::Topology::default().serialize());
    let result = match mode {
        "steal" => {
            // Every instance executes in steal mode, so every instance
            // brings a local task system.
            let cm = registry.builder().compute(compute).build()?.compute()?;
            let sys = TaskSystem::new(cm, 2, false);
            let result = run_steal_chaos(
                im,
                cmm,
                topology_json,
                total,
                tasks,
                Arc::clone(&sys),
                StealConfig::default(),
                |_| 0, // launched worlds are single-host
                chaos,
            )?;
            sys.shutdown()?;
            result
        }
        "spill" => {
            // Only the root dispatches; it gets the local execution lane.
            let local_sys = if im.is_root() {
                let cm = registry.builder().compute(compute).build()?.compute()?;
                Some(TaskSystem::new(cm, 2, false))
            } else {
                None
            };
            let local = local_sys
                .as_deref()
                .map(|sys| (sys, SpillPolicy::default()));
            let result = run_spill(im, cmm, topology_json, total, tasks, local)?;
            if let Some(sys) = &local_sys {
                sys.shutdown()?;
            }
            result
        }
        other => {
            return Err(err(format!(
                "unknown taskfarm mode '{other}' (use steal or spill)"
            )))
        }
    };
    match result {
        None => Ok(()), // worker: served until shutdown
        Some(report) => {
            let spread: Vec<String> = report
                .per_worker
                .iter()
                .map(|(rank, count)| format!("rank{rank}={count}"))
                .collect();
            println!(
                "taskfarm world={} workers={} tasks={} ok checksum={:#018x} \
                 local={} spilled={} stolen={} recovered={} steal_rpcs={}/{} \
                 lazy_bytes={} topologies={} devices={} elapsed={:.3}s",
                report.world,
                report.workers,
                report.tasks,
                report.checksum,
                report.local_tasks,
                report.spilled_tasks,
                report.stolen_tasks,
                report.recovered,
                report.steal_rpcs_attempted,
                report.steal_rpcs_succeeded,
                report.lazy_payload_bytes,
                report.gathered_topologies,
                report.total_devices,
                report.elapsed_s
            );
            println!("taskfarm spread: {}", spread.join(" "));
            Ok(())
        }
    }
}

/// The serving tier end-to-end: the root instance routes, every other
/// instance batches; the root's built-in closed-loop client verifies
/// every response payload against the reference executor.
fn worker_serve(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    registry: &Registry,
    total: usize,
    requests: u64,
    window: usize,
) -> Result<()> {
    use hicr::apps::serve::{run, ServeParams};
    let topology_json = hicr::backends::merged_topology(registry, &PluginContext::new())
        .map(|t| t.serialize())
        .unwrap_or_else(|_| hicr::Topology::default().serialize());
    let params = ServeParams {
        total,
        requests,
        window,
        ..ServeParams::default()
    };
    match run(im, cmm, topology_json, &params)? {
        None => Ok(()), // worker: served until shutdown
        Some(r) => {
            if r.checksum_failures > 0 {
                return Err(err(format!(
                    "serve: {} of {} responses failed payload verification",
                    r.checksum_failures, r.requests
                )));
            }
            println!(
                "serve world={} workers={} requests={} ok p50={:.3}ms p99={:.3}ms \
                 goodput={:.0}req/s rejected={} shed={} scale=+{}/-{} \
                 mesh_requests={} mesh_responses={} mesh_errors={} elapsed={:.3}s",
                r.world,
                r.workers,
                r.requests,
                r.p50_ms,
                r.p99_ms,
                r.goodput_rps,
                r.rejected,
                r.shed,
                r.scale_out_events,
                r.scale_in_events,
                r.mesh_requests,
                r.mesh_responses,
                r.mesh_malformed + r.mesh_exec_errors,
                r.elapsed_s
            );
            Ok(())
        }
    }
}

/// Fig. 7 demo: root tops up the instance count at runtime.
fn worker_spawntest(im: &dyn InstanceManager) -> Result<()> {
    let desired = 3;
    let template = InstanceTemplate::new(TopologyRequirements::default());
    let created = ensure_instances(im, desired, &template)?;
    if im.is_root() {
        println!(
            "root: created {} instance(s) at runtime; now {} total",
            created.len(),
            im.instances()?.len()
        );
    }
    Ok(())
}
