//! Test Case 1 (paper §5.1): two instances connected by two opposing SPSC
//! channels (bidirectional), ping-pong over message sizes from 1 B to
//! ~2.14 GB, reporting goodput G(s).
//!
//! Two result modes (DESIGN.md §2): the *modeled* series computes G(s)
//! from the calibrated interconnect profiles (this is what Fig. 8 plots —
//! the sandbox has no Infiniband), while the *measured* mode runs the real
//! protocol over the socket substrate to validate correctness and give a
//! loopback wall-clock series.

use std::sync::Arc;

use crate::core::communication::CommunicationManager;
use crate::core::error::Result;
use crate::core::ids::MemorySpaceId;
use crate::core::memory::LocalMemorySlot;
use crate::frontends::channels::spsc::{SpscConsumer, SpscProducer};
use crate::netsim::fabric::CostProfile;

/// One goodput sample.
#[derive(Debug, Clone)]
pub struct GoodputPoint {
    pub bytes: u64,
    pub goodput_bps: f64,
    pub stddev_bps: f64,
}

/// The message sizes the paper sweeps (1 B → ~2.14 GB, powers of two plus
/// the paper's end point).
pub fn paper_sizes() -> Vec<u64> {
    let mut sizes: Vec<u64> = (0..=31).map(|e| 1u64 << e).collect();
    sizes.push(2_140_000_000);
    sizes
}

/// Modeled Fig. 8 series for one backend profile.
pub fn modeled_series(profile: &CostProfile, sizes: &[u64]) -> Vec<GoodputPoint> {
    sizes
        .iter()
        .map(|&s| GoodputPoint {
            bytes: s,
            goodput_bps: profile.pingpong_goodput_bps(s),
            stddev_bps: 0.0,
        })
        .collect()
}

/// Role in a measured ping-pong run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Side {
    Pinger,
    Ponger,
}

/// Build the two opposing channels for one side. Channel A (tag, keys
/// 0/1) flows pinger→ponger; channel B (tag+1) flows back. Each channel's
/// ring lives at its consumer, with a single-message capacity as in the
/// paper.
pub fn build_channels(
    cmm: Arc<dyn CommunicationManager>,
    tag_base: u64,
    msg_size: usize,
    side: Side,
) -> Result<(SpscProducer, SpscConsumer)> {
    build_channels_with_capacity(cmm, tag_base, msg_size, 1, side)
}

/// [`build_channels`] with a configurable ring capacity — the streamed
/// (batched) variant needs rings deep enough to hold a whole batch.
pub fn build_channels_with_capacity(
    cmm: Arc<dyn CommunicationManager>,
    tag_base: u64,
    msg_size: usize,
    capacity: u64,
    side: Side,
) -> Result<(SpscProducer, SpscConsumer)> {
    let alloc = |len: usize| LocalMemorySlot::alloc(MemorySpaceId(1), len);
    // Exchanges are collectives: both sides must enter them in the same
    // global order (tag_base first, then tag_base+1) or two distributed
    // instances deadlock inside their first exchange. Ring under tag_base
    // is owned by the ponger (ping direction); ring under tag_base+1 by
    // the pinger (pong direction).
    match side {
        Side::Ponger => {
            let consumer = SpscConsumer::create(
                cmm.as_ref(),
                alloc(msg_size * capacity as usize)?,
                alloc(16)?,
                crate::core::ids::Tag(tag_base),
                0,
                msg_size,
                capacity,
            )?;
            let producer = SpscProducer::create(
                cmm,
                crate::core::ids::Tag(tag_base + 1),
                0,
                msg_size,
                capacity,
                alloc(8)?,
            )?;
            Ok((producer, consumer))
        }
        Side::Pinger => {
            let producer = SpscProducer::create(
                Arc::clone(&cmm),
                crate::core::ids::Tag(tag_base),
                0,
                msg_size,
                capacity,
                alloc(8)?,
            )?;
            let consumer = SpscConsumer::create(
                cmm.as_ref(),
                alloc(msg_size * capacity as usize)?,
                alloc(16)?,
                crate::core::ids::Tag(tag_base + 1),
                0,
                msg_size,
                capacity,
            )?;
            Ok((producer, consumer))
        }
    }
}

/// Run `reps` ping-pong round-trips of `msg_size` bytes as the pinger;
/// returns per-rep round-trip seconds.
pub fn run_pinger(
    producer: &mut SpscProducer,
    consumer: &mut SpscConsumer,
    msg_size: usize,
    reps: usize,
) -> Result<Vec<f64>> {
    let msg = vec![0xA5u8; msg_size];
    let mut buf = vec![0u8; msg_size];
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        producer.push_blocking(&msg)?;
        consumer.pop_blocking(&mut buf)?;
        times.push(t0.elapsed().as_secs_f64());
    }
    Ok(times)
}

/// Echo loop for the ponger side.
pub fn run_ponger(
    producer: &mut SpscProducer,
    consumer: &mut SpscConsumer,
    msg_size: usize,
    reps: usize,
) -> Result<()> {
    let mut buf = vec![0u8; msg_size];
    for _ in 0..reps {
        consumer.pop_blocking(&mut buf)?;
        producer.push_blocking(&buf)?;
    }
    Ok(())
}

/// Streamed pinger: each rep round-trips `batch` messages, pushed with
/// one doorbell + at most one fence (`push_batch_blocking`) and drained
/// with batch pops — the fence-amortized "after" series next to
/// [`run_pinger`]'s per-message "before". Returns per-rep round-trip
/// seconds (for the whole batch).
pub fn run_pinger_batched(
    producer: &mut SpscProducer,
    consumer: &mut SpscConsumer,
    msg_size: usize,
    batch: u64,
    reps: usize,
) -> Result<Vec<f64>> {
    let msgs = vec![0xA5u8; msg_size * batch as usize];
    let mut buf = vec![0u8; msg_size * batch as usize];
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        producer.push_batch_blocking(&msgs)?;
        let mut got = 0u64;
        while got < batch {
            let at = got as usize * msg_size;
            got += consumer.pop_batch_blocking(&mut buf[at..])?;
        }
        times.push(t0.elapsed().as_secs_f64());
    }
    Ok(times)
}

/// Echo loop for the streamed ponger: drains a whole batch, echoes it
/// back with one batch push.
pub fn run_ponger_batched(
    producer: &mut SpscProducer,
    consumer: &mut SpscConsumer,
    msg_size: usize,
    batch: u64,
    reps: usize,
) -> Result<()> {
    let mut buf = vec![0u8; msg_size * batch as usize];
    for _ in 0..reps {
        let mut got = 0u64;
        while got < batch {
            let at = got as usize * msg_size;
            got += consumer.pop_batch_blocking(&mut buf[at..])?;
        }
        producer.push_batch_blocking(&buf)?;
    }
    Ok(())
}

/// Goodput from round-trip samples: one-directional payload rate, as the
/// paper reports.
pub fn goodput_from_rtts(bytes: u64, rtts_s: &[f64]) -> GoodputPoint {
    let g: Vec<f64> = rtts_s
        .iter()
        .map(|rtt| bytes as f64 * 8.0 / (rtt / 2.0))
        .collect();
    let s = crate::util::stats::Summary::of(&g).expect("non-empty");
    GoodputPoint {
        bytes,
        goodput_bps: s.mean,
        stddev_bps: s.stddev,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::threads::ThreadsCommunicationManager;

    #[test]
    fn paper_size_sweep_bounds() {
        let sizes = paper_sizes();
        assert_eq!(sizes[0], 1);
        assert!(*sizes.last().unwrap() >= 2_140_000_000);
    }

    #[test]
    fn intra_process_pingpong_roundtrip() {
        // Both sides in one process over the threads backend validates the
        // protocol end to end.
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let msg = 64usize;
        let cmm2 = Arc::clone(&cmm);
        let ponger = std::thread::spawn(move || {
            let (mut p, mut c) = build_channels(cmm2, 7000, msg, Side::Ponger).unwrap();
            run_ponger(&mut p, &mut c, msg, 10).unwrap();
        });
        let (mut p, mut c) = build_channels(cmm, 7000, msg, Side::Pinger).unwrap();
        let times = run_pinger(&mut p, &mut c, msg, 10).unwrap();
        ponger.join().unwrap();
        assert_eq!(times.len(), 10);
        let point = goodput_from_rtts(msg as u64, &times);
        assert!(point.goodput_bps > 0.0);
    }

    #[test]
    fn intra_process_pingpong_batched_roundtrip() {
        // The streamed (fence-amortized) variant moves the same bytes.
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let msg = 64usize;
        let batch = 8u64;
        let cmm2 = Arc::clone(&cmm);
        let ponger = std::thread::spawn(move || {
            let (mut p, mut c) =
                build_channels_with_capacity(cmm2, 7100, msg, batch, Side::Ponger).unwrap();
            run_ponger_batched(&mut p, &mut c, msg, batch, 5).unwrap();
        });
        let (mut p, mut c) =
            build_channels_with_capacity(cmm, 7100, msg, batch, Side::Pinger).unwrap();
        let times = run_pinger_batched(&mut p, &mut c, msg, batch, 5).unwrap();
        ponger.join().unwrap();
        assert_eq!(times.len(), 5);
        // Whole batches flowed: 5 reps × 8 messages each way.
        assert_eq!(p.pushed(), 40);
        let point = goodput_from_rtts(msg as u64 * batch, &times);
        assert!(point.goodput_bps > 0.0);
        // The threads backend ring is directly addressable: the entire
        // streamed run must have elided every fence.
        assert_eq!(p.stats().fences, 0);
        assert_eq!(p.stats().staged_copies, 0);
    }

    #[test]
    fn modeled_series_has_paper_shape() {
        use crate::netsim::fabric::{LPF_IBVERBS_EDR, MPI_RMA_EDR};
        let sizes = paper_sizes();
        let lpf = modeled_series(&LPF_IBVERBS_EDR, &sizes);
        let mpi = modeled_series(&MPI_RMA_EDR, &sizes);
        // Small-message advantage ~70x, large-message convergence.
        let ratio_small = lpf[0].goodput_bps / mpi[0].goodput_bps;
        assert!((40.0..90.0).contains(&ratio_small));
        let last = sizes.len() - 1;
        let ratio_large = lpf[last].goodput_bps / mpi[last].goodput_bps;
        assert!((0.98..1.02).contains(&ratio_large));
    }
}
