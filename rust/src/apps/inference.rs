//! Test Case 2 (paper §5.2): heterogeneous MLP inference.
//!
//! The application is written once against a [`KernelProvider`]; swapping
//! the provider swaps the device/backend — exactly the paper's experiment
//! where the same HiCR code ran OpenBLAS kernels under Pthreads, ACL
//! kernels on an NPU, and naive OpenCL kernels on a GPU. Our providers:
//!
//! - [`NativeKernels`] — hand-written blocked f32 kernels executed through
//!   an *injected* host compute manager (the Pthreads+OpenBLAS analogue;
//!   any plugin prescribing host-closure execution units works);
//! - `backends::xlacomp::XlaKernels` — the AOT-lowered Pallas/JAX HLO
//!   executed through the `xlacomp` plugin (the ACL pre-compiled-kernel
//!   analogue); it lives with its plugin, keeping this application free
//!   of concrete backend types;
//! - [`adhoc_forward`] — the non-HiCR baseline the paper used to verify
//!   result consistency.

use std::sync::Arc;

use crate::core::compute::{ComputeManager, ExecStatus, ExecutionUnit, FnExecutionUnit};
use crate::core::error::{HicrError, Result};
use crate::core::topology::ComputeResource;
use crate::runtime::artifact::{ArtifactBundle, Tensor};

// The provider contract lives in `frontends::kernels` so backend plugins
// can implement it without importing the application layer; re-exported
// here because it is this app's kernel API.
pub use crate::frontends::kernels::KernelProvider;

// ---------------------------------------------------------------------
// Native host kernels (Pthreads/OpenBLAS analogue).
// ---------------------------------------------------------------------

/// Blocked dense f32 kernels executed via an injected compute manager —
/// no concrete backend type appears here (select one by name through the
/// plugin registry).
pub struct NativeKernels {
    weights: Arc<Vec<Tensor>>,
    dims: Vec<usize>,
    cm: Arc<dyn ComputeManager>,
}

impl NativeKernels {
    pub fn new(bundle: &ArtifactBundle, cm: Arc<dyn ComputeManager>) -> Result<NativeKernels> {
        if bundle.weights.len() != (bundle.layer_dims.len() - 1) * 2 {
            return Err(HicrError::Artifact("weight/layer count mismatch".into()));
        }
        Ok(NativeKernels {
            weights: Arc::new(bundle.weights.clone()),
            dims: bundle.layer_dims.clone(),
            cm,
        })
    }
}

/// y[b,n] = act(sum_k x[b,k] w[k,n] + bias[n]) — blocked over k for cache
/// reuse (the perf-critical host path; see EXPERIMENTS.md §Perf).
pub fn dense_forward(
    x: &[f32],
    batch: usize,
    w: &Tensor,
    bias: &Tensor,
    relu: bool,
    out: &mut [f32],
) {
    let k_dim = w.shape[0];
    let n_dim = w.shape[1];
    debug_assert_eq!(x.len(), batch * k_dim);
    debug_assert_eq!(out.len(), batch * n_dim);
    const BK: usize = 64;
    // Initialize with bias.
    for b in 0..batch {
        out[b * n_dim..(b + 1) * n_dim].copy_from_slice(&bias.data);
    }
    for k0 in (0..k_dim).step_by(BK) {
        let k1 = (k0 + BK).min(k_dim);
        for b in 0..batch {
            let xrow = &x[b * k_dim..(b + 1) * k_dim];
            let orow = &mut out[b * n_dim..(b + 1) * n_dim];
            for k in k0..k1 {
                let xv = xrow[k];
                if xv == 0.0 {
                    continue; // images are sparse-ish after relu layers
                }
                let wrow = &w.data[k * n_dim..(k + 1) * n_dim];
                for (o, wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    }
    if relu {
        for o in out.iter_mut() {
            if *o < 0.0 {
                *o = 0.0;
            }
        }
    }
}

impl KernelProvider for NativeKernels {
    fn forward(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        if x.len() != batch * self.dims[0] {
            return Err(HicrError::Bounds("input size mismatch".into()));
        }
        // Run the layer chain as one execution unit on a processing unit
        // (the paper's "provide an appropriate kernel function" pattern).
        let weights = Arc::clone(&self.weights);
        let dims = self.dims.clone();
        let input = x.to_vec();
        let result: Arc<std::sync::Mutex<Vec<f32>>> =
            Arc::new(std::sync::Mutex::new(Vec::new()));
        let r2 = Arc::clone(&result);
        let unit = FnExecutionUnit::new("mlp-native", move |_ctx| {
            let mut act = input.clone();
            for (li, wb) in weights.chunks_exact(2).enumerate() {
                let (w, b) = (&wb[0], &wb[1]);
                let relu = li + 1 < dims.len() - 1;
                let mut out = vec![0f32; batch * w.shape[1]];
                dense_forward(&act, batch, w, b, relu, &mut out);
                act = out;
            }
            *r2.lock().unwrap() = act;
        });
        let pu = self.cm.create_processing_unit(&ComputeResource {
            id: crate::core::ids::ComputeResourceId(0),
            kind: "cpu-core".into(),
            os_index: 0,
            locality: 0,
        })?;
        let state = self
            .cm
            .create_execution_state(unit as Arc<dyn ExecutionUnit>)?;
        pu.start(Arc::clone(&state))?;
        // Let the processing unit drive the state to completion. Calling
        // state.wait() here would race the unit's own driver on
        // suspendable (fiber) backends — both would resume() the same
        // state.
        pu.await_all()?;
        pu.terminate()?;
        if state.status() == ExecStatus::Failed {
            return Err(HicrError::InvalidState(
                "native kernel execution failed (panicked)".into(),
            ));
        }
        let out = result.lock().unwrap().clone();
        Ok(out)
    }

    fn backend_name(&self) -> &'static str {
        self.cm.backend_name()
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }
}

// ---------------------------------------------------------------------
// Ad-hoc (non-HiCR) baseline + evaluation driver.
// ---------------------------------------------------------------------

/// The paper's verification baseline: direct kernels, no HiCR involved.
pub fn adhoc_forward(bundle: &ArtifactBundle, x: &[f32], batch: usize) -> Vec<f32> {
    let mut act = x.to_vec();
    for (li, wb) in bundle.weights.chunks_exact(2).enumerate() {
        let (w, b) = (&wb[0], &wb[1]);
        let relu = li + 1 < bundle.layer_dims.len() - 1;
        let mut out = vec![0f32; batch * w.shape[1]];
        dense_forward(&act, batch, w, b, relu, &mut out);
        act = out;
    }
    act
}

/// Table 2 row: accuracy over `n` test images + the img-0 top score.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub backend: &'static str,
    pub accuracy: f64,
    pub img0_score: f32,
    pub img0_pred: usize,
    pub images: usize,
    pub elapsed_s: f64,
}

/// Score `n` test-set images through `provider` in batches.
pub fn evaluate(
    provider: &dyn KernelProvider,
    bundle: &ArtifactBundle,
    n: usize,
) -> Result<InferenceReport> {
    let n = n.min(bundle.test_count());
    let out_dim = *bundle.layer_dims.last().unwrap();
    let batch = provider.max_batch().min(32).max(1);
    let mut correct = 0usize;
    let mut img0_score = f32::NEG_INFINITY;
    let mut img0_pred = 0usize;
    let t0 = std::time::Instant::now();
    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let x = &bundle.test_images[i * bundle.img_dim..(i + b) * bundle.img_dim];
        let logits = provider.forward(x, b)?;
        for j in 0..b {
            let row = &logits[j * out_dim..(j + 1) * out_dim];
            let (pred, score) = row
                .iter()
                .enumerate()
                .fold((0usize, f32::NEG_INFINITY), |acc, (k, &v)| {
                    if v > acc.1 {
                        (k, v)
                    } else {
                        acc
                    }
                });
            if i + j == 0 {
                img0_score = score;
                img0_pred = pred;
            }
            if pred == bundle.test_labels[i + j] as usize {
                correct += 1;
            }
        }
        i += b;
    }
    Ok(InferenceReport {
        backend: provider.backend_name(),
        accuracy: correct as f64 / n as f64,
        img0_score,
        img0_pred,
        images: n,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor { shape, data }
    }

    #[test]
    fn dense_forward_matches_manual() {
        // x (1x2) @ w (2x3) + b, relu.
        let w = tensor(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = tensor(vec![3], vec![0.5, -100.0, 0.0]);
        let x = [1.0f32, -1.0];
        let mut out = vec![0f32; 3];
        dense_forward(&x, 1, &w, &b, true, &mut out);
        // raw: [1-4+0.5, 2-5-100, 3-6] = [-2.5, -103, -3] → relu → 0s.
        assert_eq!(out, vec![0.0, 0.0, 0.0]);
        let mut out2 = vec![0f32; 3];
        dense_forward(&x, 1, &w, &b, false, &mut out2);
        assert_eq!(out2, vec![-2.5, -103.0, -3.0]);
    }

    #[test]
    fn dense_forward_batched_consistency() {
        // Batch of 3 equals three batch-1 calls.
        let w = tensor(vec![4, 2], (0..8).map(|i| i as f32 * 0.25).collect());
        let b = tensor(vec![2], vec![0.1, -0.1]);
        let x: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        let mut all = vec![0f32; 6];
        dense_forward(&x, 3, &w, &b, true, &mut all);
        for i in 0..3 {
            let mut one = vec![0f32; 2];
            dense_forward(&x[i * 4..(i + 1) * 4], 1, &w, &b, true, &mut one);
            assert_eq!(&all[i * 2..(i + 1) * 2], &one[..]);
        }
    }
}
