//! The paper's four test-case applications (§5), written exclusively
//! against the abstract HiCR managers and frontends so each runs
//! unchanged across backends:
//!
//! - [`pingpong`] — Test Case 1: bidirectional SPSC channel ping-pong.
//! - [`inference`] — Test Case 2: MNIST-style MLP inference with
//!   swappable kernel providers (native host kernels vs AOT XLA).
//! - [`fibonacci`] — Test Case 3: fine-grained recursive task DAG.
//! - [`jacobi`] — Test Case 4: coarse-grained 3-D Jacobi heat solver,
//!   thread-parallel and distributed (halo exchange over one-sided puts).
//! - [`taskfarm`] — the Fig. 7 deployment pattern as an app: elastic
//!   ramp-up, topology gathering and master/worker farming over the RPC
//!   mesh.
//! - [`serve`] — the ROADMAP north-star composition: a multi-instance
//!   inference serving tier (sharded router + continuous-batching
//!   workers) with a built-in verifying closed-loop client.
//! - [`stencil`] — arbitrary-radius 1-D stencil over the hdarray
//!   frontend: declared distribution, derived halos, bitwise-verified
//!   against the sequential reference.

pub mod fibonacci;
pub mod inference;
pub mod jacobi;
pub mod pingpong;
pub mod serve;
pub mod stencil;
pub mod taskfarm;
