//! Arbitrary-radius 1-D stencil app — the first member of the scenario
//! family the hdarray frontend opens (ROADMAP): the *same* ~20 lines
//! drive any radius, any world size and either distribution, because
//! owner maps, halo channels and sweep DAG edges are derived, not
//! hand-rolled. The root verifies the distributed result **bitwise**
//! against the sequential reference run with the shared kernel, so the
//! launch smoke can grep a `verified=ok` line.

use std::sync::Arc;
use std::time::Instant;

use crate::core::communication::CommunicationManager;
use crate::core::error::Result;
use crate::core::ids::MemorySpaceId;
use crate::core::memory::LocalMemorySlot;
use crate::frontends::hdarray::{sequential_sweeps, Distribution, HdArray, Layout, Stencil};
use crate::frontends::tasking::TaskSystem;

/// Clipped box average: each element becomes the mean of its radius-`r`
/// window intersected with the array. Pure and order-deterministic, so
/// every execution plan produces bitwise identical values.
pub struct BoxKernel {
    /// Global array length (for window clipping).
    pub len: usize,
    /// Window radius — any value; wider than a neighbour's partition
    /// means multi-hop halo links, all derived.
    pub radius: usize,
}

impl Stencil for BoxKernel {
    fn radius(&self) -> usize {
        self.radius
    }

    fn apply(&self, prev: &[f32], base: usize, lo: usize, hi: usize, out: &mut [f32]) {
        for g in lo..hi {
            let a = g.saturating_sub(self.radius);
            let b = (g + self.radius + 1).min(self.len);
            let mut sum = 0.0f32;
            for i in a..b {
                sum += prev[i - base];
            }
            out[g - lo] = sum / (b - a) as f32;
        }
    }
}

/// Deterministic non-constant initial condition.
pub fn default_init(g: usize) -> f32 {
    (g % 17) as f32 * 0.25 - 1.0
}

/// Root-side outcome of a distributed stencil run.
#[derive(Debug, Clone)]
pub struct StencilReport {
    pub len: usize,
    pub iters: usize,
    pub radius: usize,
    /// Max |distributed − sequential| over the gathered array.
    pub residual: f64,
    /// True iff the gathered array is bitwise equal to the reference.
    pub verified: bool,
    pub elapsed_s: f64,
}

/// Run `iters` sweeps of the box kernel over a declared distribution.
/// Collective over `ranks`; the root (tree position 0) re-runs the
/// sequential reference, verifies bitwise, and returns the report.
#[allow(clippy::too_many_arguments)]
pub fn run_distributed(
    cmm: Arc<dyn CommunicationManager>,
    system: &TaskSystem,
    me_pos: usize,
    ranks: &[u32],
    dist: Distribution,
    len: usize,
    iters: usize,
    radius: usize,
    probe: Option<Arc<dyn Fn() -> Result<Vec<u32>> + Send + Sync>>,
) -> Result<Option<StencilReport>> {
    let layout = Layout { len, parts: ranks.len(), dist, radius };
    let alloc = |l| LocalMemorySlot::alloc(MemorySpaceId(1), l);
    let t0 = Instant::now();
    let mut arr = HdArray::build(cmm, 0x57E, me_pos, ranks, layout, default_init, alloc)?;
    if let Some(p) = probe {
        arr.set_liveness(p);
    }
    arr.run_sweeps(system, Arc::new(BoxKernel { len, radius }), iters, 4)?;
    let Some(global) = arr.gather_global()? else {
        return Ok(None);
    };
    let elapsed_s = t0.elapsed().as_secs_f64();
    let want = sequential_sweeps(len, &BoxKernel { len, radius }, default_init, iters);
    let residual = global
        .iter()
        .zip(&want)
        .map(|(a, b)| (*a as f64 - *b as f64).abs())
        .fold(0.0f64, f64::max);
    Ok(Some(StencilReport {
        len,
        iters,
        radius,
        residual,
        verified: global == want,
        elapsed_s,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::threads::ThreadsCommunicationManager;
    use crate::core::instance::testworld::local_world;
    use crate::core::instance::InstanceManager;

    fn system() -> Arc<TaskSystem> {
        let cm = crate::backends::registry()
            .builder()
            .compute("threads")
            .build()
            .unwrap()
            .compute()
            .unwrap();
        TaskSystem::new(cm, 2, false)
    }

    #[test]
    fn single_instance_is_bitwise_verified() {
        for radius in [0, 1, 4, 9] {
            let cmm: Arc<dyn CommunicationManager> = Arc::new(ThreadsCommunicationManager::new());
            let sys = system();
            let report = run_distributed(
                cmm,
                &sys,
                0,
                &[0],
                Distribution::Block,
                64,
                3,
                radius,
                None,
            )
            .unwrap()
            .expect("single instance is the root");
            sys.shutdown().unwrap();
            assert!(report.verified, "radius {radius}: residual {}", report.residual);
            assert_eq!(report.residual, 0.0);
        }
    }

    /// Radius wider than a neighbour's whole partition: the derived plan
    /// contains multi-hop links and must still verify bitwise.
    #[test]
    fn wide_radius_crosses_multiple_partitions() {
        for dist in [Distribution::Block, Distribution::Cyclic] {
            let n = 3;
            let cmm: Arc<dyn CommunicationManager> = Arc::new(ThreadsCommunicationManager::new());
            let mut handles = Vec::new();
            for (pos, im) in local_world(n).into_iter().enumerate() {
                let cmm = cmm.clone();
                handles.push(std::thread::spawn(move || {
                    let sys = system();
                    let ranks: Vec<u32> = (0..n as u32).collect();
                    let report =
                        run_distributed(cmm, &sys, pos, &ranks, dist, 16, 3, 7, None).unwrap();
                    sys.shutdown().unwrap();
                    im.barrier().unwrap();
                    report
                }));
            }
            let reports: Vec<Option<StencilReport>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            let root = reports[0].as_ref().expect("root reports");
            assert!(root.verified, "{dist:?}: residual {}", root.residual);
            assert!(reports[1].is_none() && reports[2].is_none());
        }
    }
}
