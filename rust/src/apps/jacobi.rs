//! Test Case 4 (paper §5.4): three-dimensional Jacobi heat solver with a
//! 13-point averaging stencil (center + axis neighbours at distance 1 and
//! 2), grid decomposed into `lx × ly × lz` subgrids, one worker task per
//! subgrid per iteration; plus the distributed variant exchanging halo
//! planes between instances over one-sided puts (Fig. 11).

use std::sync::Arc;

use crate::core::communication::{CommunicationManager, DataEndpoint};
use crate::core::error::{HicrError, Result};
use crate::core::ids::{Key, MemorySpaceId, Tag};
use crate::core::memory::LocalMemorySlot;
use crate::frontends::hdarray;
use crate::frontends::tasking::{TaskHandle, TaskSystem};

/// Flops per updated grid point: 12 adds + 1 multiply.
pub const FLOPS_PER_POINT: u64 = 13;

/// A (next, prev) pair of flattened n×n×n f64 grids with shared interior.
pub struct Grid {
    pub n: usize,
    bufs: [Arc<GridBuf>; 2],
}

/// Interior-mutable f64 buffer: disjoint subgrid tasks write their own
/// regions (the HiCR one-sided contract; same rationale as SlotBuffer).
pub struct GridBuf {
    data: std::cell::UnsafeCell<Vec<f64>>,
}

// SAFETY: access goes through slice()/slice_mut(), whose callers uphold
// the disjoint-writes contract below; the type hands out no references
// on its own. Same rationale as core::memory::SlotBuffer.
unsafe impl Send for GridBuf {}
// SAFETY: see the Send impl above.
unsafe impl Sync for GridBuf {}

impl GridBuf {
    fn new(len: usize) -> Arc<Self> {
        Arc::new(Self {
            data: std::cell::UnsafeCell::new(vec![0.0; len]),
        })
    }

    /// # Safety
    /// Callers must write disjoint regions (one task per subgrid).
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice_mut(&self) -> &mut [f64] {
        &mut *self.data.get()
    }

    fn slice(&self) -> &[f64] {
        // SAFETY: readers only look at regions no concurrent task writes
        // (stencil reads prev while tasks write next; DAG edges order the
        // cross-iteration swap).
        unsafe { &*self.data.get() }
    }
}

impl Grid {
    /// Initialize with a hot plane at x = 0 (Dirichlet-ish source).
    pub fn new(n: usize) -> Grid {
        let bufs = [GridBuf::new(n * n * n), GridBuf::new(n * n * n)];
        {
            // SAFETY: the buffers were just created; no other reference
            // exists before Grid::new returns.
            let b0 = unsafe { bufs[0].slice_mut() };
            let b1 = unsafe { bufs[1].slice_mut() };
            for y in 0..n {
                for z in 0..n {
                    b0[y * n + z] = 1.0; // x = 0 plane
                    b1[y * n + z] = 1.0;
                }
            }
        }
        Grid { n, bufs }
    }

    #[inline]
    pub fn idx(n: usize, x: usize, y: usize, z: usize) -> usize {
        (x * n + y) * n + z
    }

    /// Read the current (last-written) buffer.
    pub fn current(&self, iters_done: usize) -> &[f64] {
        self.bufs[iters_done % 2].slice()
    }

    /// Checksum for cross-variant equivalence tests.
    pub fn checksum(&self, iters_done: usize) -> f64 {
        self.current(iters_done).iter().sum()
    }
}

/// Update the subgrid `[x0,x1) × [y0,y1) × [z0,z1)` from `prev` into
/// `next`. Boundary points (where any distance-2 neighbour would leave the
/// grid) keep their previous value (insulated boundary).
#[allow(clippy::too_many_arguments)]
fn stencil_block(
    prev: &[f64],
    next: &mut [f64],
    n: usize,
    x0: usize,
    x1: usize,
    y0: usize,
    y1: usize,
    z0: usize,
    z1: usize,
) -> u64 {
    let mut updated = 0u64;
    let inv = 1.0 / 13.0;
    for x in x0..x1 {
        for y in y0..y1 {
            let row = (x * n + y) * n;
            if x < 2 || x >= n - 2 || y < 2 || y >= n - 2 {
                next[row + z0..row + z1].copy_from_slice(&prev[row + z0..row + z1]);
                continue;
            }
            for z in z0..z1 {
                if z < 2 || z >= n - 2 {
                    next[row + z] = prev[row + z];
                    continue;
                }
                let c = row + z;
                let sum = prev[c]
                    + prev[c - 1]
                    + prev[c + 1]
                    + prev[c - 2]
                    + prev[c + 2]
                    + prev[c - n]
                    + prev[c + n]
                    + prev[c - 2 * n]
                    + prev[c + 2 * n]
                    + prev[c - n * n]
                    + prev[c + n * n]
                    + prev[c - 2 * n * n]
                    + prev[c + 2 * n * n];
                next[c] = sum * inv;
                updated += 1;
            }
        }
    }
    updated
}

/// Result of a Jacobi run.
#[derive(Debug, Clone)]
pub struct JacobiRun {
    pub n: usize,
    pub iterations: usize,
    pub elapsed_s: f64,
    pub gflops: f64,
    pub checksum: f64,
}

/// Single-instance solver: `lx × ly × lz` tasks per iteration on `system`
/// (the Fig. 10 experiment).
pub fn run_local(
    system: &TaskSystem,
    grid: &mut Grid,
    iterations: usize,
    mesh: (usize, usize, usize),
) -> Result<JacobiRun> {
    let n = grid.n;
    let (lx, ly, lz) = mesh;
    if lx == 0 || ly == 0 || lz == 0 || lx > n || ly > n || lz > n {
        return Err(HicrError::Rejected(format!("bad thread mesh {mesh:?}")));
    }
    let total_updates = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let t0 = std::time::Instant::now();
    for it in 0..iterations {
        let prev = Arc::clone(&grid.bufs[it % 2]);
        let next = Arc::clone(&grid.bufs[(it + 1) % 2]);
        let updates = Arc::clone(&total_updates);
        system.run("jacobi-iter", move |ctx| {
            for bx in 0..lx {
                for by in 0..ly {
                    for bz in 0..lz {
                        let prev = Arc::clone(&prev);
                        let next = Arc::clone(&next);
                        let updates = Arc::clone(&updates);
                        let (x0, x1) = split(n, lx, bx);
                        let (y0, y1) = split(n, ly, by);
                        let (z0, z1) = split(n, lz, bz);
                        ctx.spawn("stencil", move |_| {
                            // SAFETY: subgrids are disjoint by construction.
                            let next_mut = unsafe { next.slice_mut() };
                            let u = stencil_block(
                                prev.slice(),
                                next_mut,
                                n,
                                x0,
                                x1,
                                y0,
                                y1,
                                z0,
                                z1,
                            );
                            // relaxed-ok: telemetry counter; no data is published through this atomic
                            updates.fetch_add(u, std::sync::atomic::Ordering::Relaxed);
                        });
                    }
                }
            }
            ctx.wait_children();
        })?;
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    // relaxed-ok: telemetry counter; no data is published through this atomic
    let flops = total_updates.load(std::sync::atomic::Ordering::Relaxed) * FLOPS_PER_POINT;
    Ok(JacobiRun {
        n,
        iterations,
        elapsed_s,
        gflops: flops as f64 / elapsed_s / 1e9,
        checksum: grid.checksum(iterations),
    })
}

/// Per-axis stencil dependencies: for each block range, the indices of
/// every block whose range intersects it expanded by the stencil radius
/// (2) on both sides. A block's iteration-`k` task depends on the
/// iteration-`k-1` tasks of exactly the cartesian product of these sets
/// — both the cells it reads (RAW) and the readers of the cells it
/// overwrites (WAR, double-buffering) lie inside that footprint.
fn axis_neighbors(ranges: &[(usize, usize)]) -> Vec<Vec<usize>> {
    ranges
        .iter()
        .map(|&(start, end)| {
            let lo = start.saturating_sub(2);
            let hi = end + 2;
            ranges
                .iter()
                .enumerate()
                .filter(|&(_, &(cs, ce))| cs < hi && ce > lo)
                .map(|(i, _)| i)
                .collect()
        })
        .collect()
}

/// Single-instance solver expressed as one explicit task DAG across
/// *all* iterations: block (bx, by, bz) at iteration `k` is gated by
/// `spawn_after` on the iteration-`k-1` blocks in its halo footprint,
/// instead of a global barrier (`wait_children`) per iteration. Later
/// sweeps therefore start in regions whose halos are ready while slow
/// blocks of the previous sweep still run — the halo pipeline the
/// work-stealing scheduler exploits.
pub fn run_local_dag(
    system: &TaskSystem,
    grid: &mut Grid,
    iterations: usize,
    mesh: (usize, usize, usize),
) -> Result<JacobiRun> {
    let n = grid.n;
    let (lx, ly, lz) = mesh;
    if lx == 0 || ly == 0 || lz == 0 || lx > n || ly > n || lz > n {
        return Err(HicrError::Rejected(format!("bad thread mesh {mesh:?}")));
    }
    let xr: Vec<(usize, usize)> = (0..lx).map(|i| split(n, lx, i)).collect();
    let yr: Vec<(usize, usize)> = (0..ly).map(|i| split(n, ly, i)).collect();
    let zr: Vec<(usize, usize)> = (0..lz).map(|i| split(n, lz, i)).collect();
    let (nbx, nby, nbz) = (axis_neighbors(&xr), axis_neighbors(&yr), axis_neighbors(&zr));
    let bufs = [Arc::clone(&grid.bufs[0]), Arc::clone(&grid.bufs[1])];
    let total_updates = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let updates_root = Arc::clone(&total_updates);
    let t0 = std::time::Instant::now();
    system.run("jacobi-dag", move |ctx| {
        let mut prev_handles: Vec<TaskHandle> = Vec::new();
        for it in 0..iterations {
            let mut cur = Vec::with_capacity(lx * ly * lz);
            for bx in 0..lx {
                for by in 0..ly {
                    for bz in 0..lz {
                        let deps: Vec<TaskHandle> = if it == 0 {
                            Vec::new()
                        } else {
                            let mut d = Vec::new();
                            for &ix in &nbx[bx] {
                                for &iy in &nby[by] {
                                    for &iz in &nbz[bz] {
                                        d.push(
                                            prev_handles[(ix * ly + iy) * lz + iz]
                                                .clone(),
                                        );
                                    }
                                }
                            }
                            d
                        };
                        let prev = Arc::clone(&bufs[it % 2]);
                        let next = Arc::clone(&bufs[(it + 1) % 2]);
                        let updates = Arc::clone(&updates_root);
                        let ((x0, x1), (y0, y1), (z0, z1)) = (xr[bx], yr[by], zr[bz]);
                        cur.push(ctx.spawn_after(&deps, "stencil", move |_| {
                            // SAFETY: subgrids are disjoint within an
                            // iteration, and the spawn_after halo edges
                            // order every cross-iteration read/write on
                            // the shared double buffers.
                            let next_mut = unsafe { next.slice_mut() };
                            let u = stencil_block(
                                prev.slice(),
                                next_mut,
                                n,
                                x0,
                                x1,
                                y0,
                                y1,
                                z0,
                                z1,
                            );
                            // relaxed-ok: telemetry counter; no data is published through this atomic
                            updates.fetch_add(u, std::sync::atomic::Ordering::Relaxed);
                        }));
                    }
                }
            }
            prev_handles = cur;
        }
        ctx.wait_children();
    })?;
    let elapsed_s = t0.elapsed().as_secs_f64();
    let flops =
        // relaxed-ok: telemetry counter; no data is published through this atomic
        total_updates.load(std::sync::atomic::Ordering::Relaxed) * FLOPS_PER_POINT;
    Ok(JacobiRun {
        n,
        iterations,
        elapsed_s,
        gflops: flops as f64 / elapsed_s / 1e9,
        checksum: grid.checksum(iterations),
    })
}

/// Even split of `n` into `parts`, returning the `i`-th range.
pub fn split(n: usize, parts: usize, i: usize) -> (usize, usize) {
    let base = n / parts;
    let rem = n % parts;
    let start = i * base + i.min(rem);
    let len = base + usize::from(i < rem);
    (start, start + len)
}

/// Sequential reference (for equivalence tests).
pub fn run_sequential(grid: &mut Grid, iterations: usize) -> f64 {
    let n = grid.n;
    for it in 0..iterations {
        let prev = Arc::clone(&grid.bufs[it % 2]);
        let next = Arc::clone(&grid.bufs[(it + 1) % 2]);
        // SAFETY: sequential reference path — &mut Grid guarantees
        // exclusive access to both buffers.
        let next_mut = unsafe { next.slice_mut() };
        stencil_block(prev.slice(), next_mut, n, 0, n, 0, n, 0, n);
    }
    grid.checksum(iterations)
}

// ---------------------------------------------------------------------
// Distributed variant (Fig. 11): slab decomposition along x, halo planes
// exchanged through one-sided puts after each iteration.
// ---------------------------------------------------------------------

/// How an instance waits for communication completion — the knob behind
/// the paper's Fig. 11 finding (nOS-V's eager polling interferes with
/// computation; Pthreads+Boost blocks quietly).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommWaitMode {
    Blocking,
    EagerPolling,
}

/// Distributed Jacobi on `p` instances, slab-decomposed along x. Each
/// instance holds `local_nx + 4` planes (2 ghost planes each side).
/// Returns this instance's run stats (checksum is instance-local).
#[allow(clippy::too_many_arguments)]
pub fn run_distributed(
    cmm: &Arc<dyn CommunicationManager>,
    system: &TaskSystem,
    rank: u32,
    world: u32,
    n: usize,
    iterations: usize,
    thread_mesh: (usize, usize, usize),
    wait_mode: CommWaitMode,
) -> Result<JacobiRun> {
    let (x0, x1) = split(n, world as usize, rank as usize);
    let local_nx = x1 - x0;
    let plane = n * n;
    let ext_nx = local_nx + 4; // 2 ghost planes per side
    // Two extended buffers as HiCR slots (f64 little-endian).
    let make = || LocalMemorySlot::alloc(crate::core::ids::MemorySpaceId(1), ext_nx * plane * 8);
    let bufs = [make()?, make()?];
    // Initialize: hot plane at global x = 0.
    if x0 == 0 {
        let hot = vec![1.0f64; plane];
        let mut bytes = Vec::with_capacity(plane * 8);
        for v in &hot {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for b in &bufs {
            b.write_at(2 * plane * 8, &bytes)?; // first owned plane
        }
    }
    // Exchange ghost windows: 4 windows per buffer (low/high ghost pairs).
    // Key layout: rank*16 + buf*4 + {0: low ghosts, 1: high ghosts}.
    let tag = Tag(0xA11_0);
    let mut my_slots = Vec::new();
    for (bi, b) in bufs.iter().enumerate() {
        my_slots.push((Key(rank as u64 * 16 + bi as u64 * 4), b.clone()));
    }
    let exchanged = cmm.exchange_global_slots(tag, &my_slots)?;
    let t0 = std::time::Instant::now();
    let mut total_updates = 0u64;
    for it in 0..iterations {
        let prev = &bufs[it % 2];
        let next = &bufs[(it + 1) % 2];
        // Compute on owned planes [2, 2+local_nx) of the extended grid.
        let prev_f = slot_as_f64(prev, ext_nx * plane);
        let mut next_f = vec![0.0f64; ext_nx * plane];
        next_f.copy_from_slice(&prev_f);
        let updates = Arc::new(std::sync::atomic::AtomicU64::new(0));
        {
            let (lx, ly, lz) = thread_mesh;
            let prev_arc = Arc::new(prev_f);
            let next_arc = Arc::new(std::sync::Mutex::new(next_f));
            let u2 = Arc::clone(&updates);
            let prev2 = Arc::clone(&prev_arc);
            let next2 = Arc::clone(&next_arc);
            system.run("jacobi-dist-iter", move |ctx| {
                for bx in 0..lx {
                    for by in 0..ly {
                        for bz in 0..lz {
                            let prev = Arc::clone(&prev2);
                            let next = Arc::clone(&next2);
                            let u = Arc::clone(&u2);
                            let (sx0, sx1) = split(local_nx, lx, bx);
                            let (sy0, sy1) = split(n, ly, by);
                            let (sz0, sz1) = split(n, lz, bz);
                            ctx.spawn("stencil", move |_| {
                                let mut block = dist_stencil(
                                    &prev,
                                    ext_nx,
                                    n,
                                    x0,
                                    2 + sx0,
                                    2 + sx1,
                                    sy0,
                                    sy1,
                                    sz0,
                                    sz1,
                                );
                                let mut next = next.lock().unwrap();
                                for (off, v) in block.drain(..) {
                                    next[off] = v;
                                }
                                // relaxed-ok: telemetry counter; no data is published through this atomic
                                u.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            });
                        }
                    }
                }
                ctx.wait_children();
            })?;
            next_f = Arc::try_unwrap(next_arc)
                .map_err(|_| HicrError::InvalidState("next buffer leaked".into()))?
                .into_inner()
                .unwrap();
        }
        total_updates += (local_nx * n * n) as u64;
        // Write back into the slot.
        write_f64(next, &next_f)?;
        // Halo exchange: send our two boundary owned planes to each
        // neighbour's ghost region of the *next* buffer. Both puts are
        // initiated asynchronously and covered by the single fence below
        // (one synchronization point per iteration, as the model allows).
        let next_bi = (it + 1) % 2;
        let mut halo_puts = Vec::with_capacity(2);
        if rank > 0 {
            let nb_key = Key((rank as u64 - 1) * 16 + next_bi as u64 * 4);
            let g = exchanged.get(&nb_key).ok_or_else(|| {
                HicrError::Collective(format!("missing neighbour window {nb_key}"))
            })?;
            let (nx0, nx1) = split(n, world as usize, rank as usize - 1);
            let nb_ext = (nx1 - nx0) + 4;
            // Our planes [2, 4) → neighbour's high ghosts [nb_ext-2, nb_ext).
            halo_puts.push(cmm.memcpy_async(
                &DataEndpoint::Global(g.clone()),
                (nb_ext - 2) * plane * 8,
                &DataEndpoint::Local(next.clone()),
                2 * plane * 8,
                2 * plane * 8,
            )?);
        }
        if rank + 1 < world {
            let nb_key = Key((rank as u64 + 1) * 16 + next_bi as u64 * 4);
            let g = exchanged.get(&nb_key).ok_or_else(|| {
                HicrError::Collective(format!("missing neighbour window {nb_key}"))
            })?;
            // Our planes [2+local_nx-2, 2+local_nx) → neighbour's low
            // ghosts [0, 2).
            halo_puts.push(cmm.memcpy_async(
                &DataEndpoint::Global(g.clone()),
                0,
                &DataEndpoint::Local(next.clone()),
                (local_nx) * plane * 8, // = 2 + local_nx - 2
                2 * plane * 8,
            )?);
        }
        match wait_mode {
            CommWaitMode::Blocking => cmm.fence(tag)?,
            CommWaitMode::EagerPolling => {
                // nOS-V-style: spin on the completion handles instead of
                // blocking — the eager polling that interferes with
                // computation on the core (Fig. 11's finding). The final
                // fence is still the correctness guarantee.
                while !halo_puts.iter().all(|h| h.is_complete()) {
                    for _ in 0..2_000 {
                        std::hint::spin_loop();
                    }
                }
                cmm.fence(tag)?;
            }
        }
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let cur = slot_as_f64(&bufs[iterations % 2], ext_nx * plane);
    let checksum: f64 = cur[2 * plane..(2 + local_nx) * plane].iter().sum();
    Ok(JacobiRun {
        n,
        iterations,
        elapsed_s,
        gflops: (total_updates * FLOPS_PER_POINT) as f64 / elapsed_s / 1e9,
        checksum,
    })
}

/// Distance-1/2 axis stencil over the extended (ghosted) grid; returns
/// (offset, value) updates for *global-interior* points only (`gx0` is
/// the slab's global x origin — global boundary planes stay untouched,
/// matching the single-instance solver).
#[allow(clippy::too_many_arguments)]
fn dist_stencil(
    prev: &[f64],
    ext_nx: usize,
    n: usize,
    gx0: usize,
    x0: usize,
    x1: usize,
    y0: usize,
    y1: usize,
    z0: usize,
    z1: usize,
) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    let inv = 1.0 / 13.0;
    let nn = n * n;
    for x in x0..x1 {
        if x < 2 || x + 2 >= ext_nx {
            continue;
        }
        let global_x = gx0 + x - 2;
        if global_x < 2 || global_x >= n - 2 {
            continue;
        }
        for y in y0..y1 {
            if y < 2 || y + 2 >= n {
                continue;
            }
            for z in z0..z1 {
                if z < 2 || z + 2 >= n {
                    continue;
                }
                let c = (x * n + y) * n + z;
                let sum = prev[c]
                    + prev[c - 1]
                    + prev[c + 1]
                    + prev[c - 2]
                    + prev[c + 2]
                    + prev[c - n]
                    + prev[c + n]
                    + prev[c - 2 * n]
                    + prev[c + 2 * n]
                    + prev[c - nn]
                    + prev[c + nn]
                    + prev[c - 2 * nn]
                    + prev[c + 2 * nn];
                out.push((c, sum * inv));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// HDArray client: the same solver as a declared distribution — the
// hand-rolled pipeline above survives as the ablation baseline
// (`launch -- jacobi … pipeline`).
// ---------------------------------------------------------------------

/// The 13-point kernel as an [`hdarray::Stencil`] over the flattened
/// x-major grid: radius `2·n²` reaches two x-planes either side, so a
/// block distribution is exactly the Fig. 11 slab decomposition — but
/// the owner maps, halo channels and per-sweep DAG edges are all
/// derived by the frontend instead of hand-rolled.
pub struct Jacobi13 {
    /// Grid side length.
    pub n: usize,
}

impl hdarray::Stencil for Jacobi13 {
    fn radius(&self) -> usize {
        2 * self.n * self.n
    }

    fn apply(&self, prev: &[f32], base: usize, lo: usize, hi: usize, out: &mut [f32]) {
        let n = self.n;
        let nn = n * n;
        let inv = 1.0f32 / 13.0;
        for g in lo..hi {
            let (x, y, z) = (g / nn, (g % nn) / n, g % n);
            let c = g - base;
            out[g - lo] = if x < 2 || x >= n - 2 || y < 2 || y >= n - 2 || z < 2 || z >= n - 2 {
                prev[c]
            } else {
                (prev[c]
                    + prev[c - 1]
                    + prev[c + 1]
                    + prev[c - 2]
                    + prev[c + 2]
                    + prev[c - n]
                    + prev[c + n]
                    + prev[c - 2 * n]
                    + prev[c + 2 * n]
                    + prev[c - nn]
                    + prev[c + nn]
                    + prev[c - 2 * nn]
                    + prev[c + 2 * nn])
                    * inv
            };
        }
    }
}

/// The initial condition of [`Grid::new`] as a pure global function
/// (hot plane at x = 0).
pub fn jacobi_init(n: usize) -> impl Fn(usize) -> f32 + Clone {
    let nn = n * n;
    move |g| if g < nn { 1.0 } else { 0.0 }
}

/// Distributed Jacobi as an hdarray client: declare the distribution,
/// run the sweeps, gather on the root. The whole halo machinery of
/// [`run_distributed`] reduces to these few lines. Returns the global
/// checksum on the root (tree position 0), `None` elsewhere.
pub fn run_hdarray(
    cmm: Arc<dyn CommunicationManager>,
    system: &TaskSystem,
    me_pos: usize,
    ranks: &[u32],
    dist: hdarray::Distribution,
    n: usize,
    iterations: usize,
) -> Result<Option<f64>> {
    let kernel = Arc::new(Jacobi13 { n });
    let layout = hdarray::Layout {
        len: n * n * n,
        parts: ranks.len(),
        dist,
        radius: 2 * n * n,
    };
    let alloc = |len| LocalMemorySlot::alloc(MemorySpaceId(1), len);
    let mut arr =
        hdarray::HdArray::build(cmm, 0xA11, me_pos, ranks, layout, jacobi_init(n), alloc)?;
    arr.run_sweeps(system, kernel, iterations, 4)?;
    Ok(arr
        .gather_global()?
        .map(|global| global.iter().map(|&v| v as f64).sum()))
}

fn slot_as_f64(slot: &LocalMemorySlot, count: usize) -> Vec<f64> {
    let mut bytes = vec![0u8; count * 8];
    slot.read_at(0, &mut bytes).expect("in-bounds");
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn write_f64(slot: &LocalMemorySlot, data: &[f64]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 8);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    slot.write_at(0, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn system_for(backend: &str) -> Arc<TaskSystem> {
        let cm = crate::backends::registry()
            .builder()
            .compute(backend)
            .build()
            .unwrap()
            .compute()
            .unwrap();
        TaskSystem::new(cm, 4, false)
    }

    #[test]
    fn split_covers_range() {
        for (n, parts) in [(10, 3), (7, 7), (100, 8), (5, 1)] {
            let mut covered = 0;
            for i in 0..parts {
                let (a, b) = split(n, parts, i);
                assert_eq!(a, covered);
                covered = b;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let n = 16;
        let iters = 5;
        let mut seq = Grid::new(n);
        let want = run_sequential(&mut seq, iters);
        for backend in ["coro", "nosv", "threads"] {
            let sys = system_for(backend);
            let mut grid = Grid::new(n);
            let run = run_local(&sys, &mut grid, iters, (2, 2, 2)).unwrap();
            sys.shutdown().unwrap();
            assert!(
                (run.checksum - want).abs() < 1e-9,
                "{backend}: {} != {want}",
                run.checksum
            );
            assert!(run.gflops > 0.0);
        }
    }

    #[test]
    fn axis_neighbors_cover_stencil_footprint() {
        // 10 cells in 5 blocks of 2: radius-2 reaches one block away.
        let ranges: Vec<(usize, usize)> = (0..5).map(|i| split(10, 5, i)).collect();
        let nb = axis_neighbors(&ranges);
        assert_eq!(nb[0], vec![0, 1]);
        assert_eq!(nb[2], vec![1, 2, 3]);
        assert_eq!(nb[4], vec![3, 4]);
        // One fat block depends only on itself.
        assert_eq!(axis_neighbors(&[(0, 10)]), vec![vec![0]]);
    }

    #[test]
    fn dag_pipeline_matches_sequential() {
        let n = 16;
        let iters = 5;
        let mut seq = Grid::new(n);
        let want = run_sequential(&mut seq, iters);
        for backend in ["coro", "nosv", "threads"] {
            let sys = system_for(backend);
            let mut grid = Grid::new(n);
            let run = run_local_dag(&sys, &mut grid, iters, (2, 2, 2)).unwrap();
            sys.shutdown().unwrap();
            assert!(
                (run.checksum - want).abs() < 1e-9,
                "{backend}: {} != {want}",
                run.checksum
            );
            // One task per block per iteration, plus the root.
            assert_eq!(sys.tasks_executed(), (iters * 8 + 1) as u64);
        }
    }

    #[test]
    fn heat_diffuses_inward() {
        let n = 12;
        let mut grid = Grid::new(n);
        run_sequential(&mut grid, 10);
        let cur = grid.current(10);
        // Energy must have moved off the x=0 plane into the interior.
        let interior = cur[Grid::idx(n, 5, 5, 5)];
        assert!(interior >= 0.0);
        let near_source = cur[Grid::idx(n, 2, 5, 5)];
        assert!(
            near_source > interior,
            "temperature should decay away from the source"
        );
        assert!(near_source > 0.0);
    }

    /// Satellite 2: hdarray jacobi ≡ sequential reference ≡ the
    /// retained hand-rolled DAG, across all three compute backends and
    /// both distributions. The hdarray result must equal the shared-
    /// kernel f32 reference *bitwise*; the f64 paths agree to rounding.
    #[test]
    fn hdarray_matches_dag_and_sequential() {
        use crate::backends::threads::ThreadsCommunicationManager;
        use crate::core::instance::testworld::local_world;
        use crate::core::instance::InstanceManager;
        use crate::frontends::hdarray::Distribution;
        let n = 8;
        let iters = 4;
        let world = 2;
        let mut seq = Grid::new(n);
        let want = run_sequential(&mut seq, iters);
        let ref32 =
            hdarray::sequential_sweeps(n * n * n, &Jacobi13 { n }, jacobi_init(n), iters);
        let want32: f64 = ref32.iter().map(|&v| v as f64).sum();
        assert!((want32 - want).abs() < 1e-2, "f32 reference drifted: {want32} vs {want}");
        for backend in ["coro", "nosv", "threads"] {
            let sys = system_for(backend);
            let mut grid = Grid::new(n);
            let dag = run_local_dag(&sys, &mut grid, iters, (2, 2, 2)).unwrap();
            sys.shutdown().unwrap();
            assert!((dag.checksum - want).abs() < 1e-9, "{backend}: ablation DAG drifted");
            for dist in [Distribution::Block, Distribution::Cyclic] {
                let cmm: Arc<dyn CommunicationManager> =
                    Arc::new(ThreadsCommunicationManager::new());
                let mut handles = Vec::new();
                for (pos, im) in local_world(world).into_iter().enumerate() {
                    let cmm = cmm.clone();
                    let backend = backend.to_string();
                    handles.push(std::thread::spawn(move || {
                        let sys = system_for(&backend);
                        let ranks: Vec<u32> = (0..world as u32).collect();
                        let got =
                            run_hdarray(cmm, &sys, pos, &ranks, dist, n, iters).unwrap();
                        sys.shutdown().unwrap();
                        im.barrier().unwrap();
                        got
                    }));
                }
                let sums: Vec<Option<f64>> =
                    handles.into_iter().map(|h| h.join().unwrap()).collect();
                assert!(sums[1].is_none(), "non-root must not gather");
                let got = sums[0].expect("root assembles the global array");
                assert_eq!(got, want32, "{backend}/{dist:?}: not bitwise-equal to reference");
            }
        }
    }

    #[test]
    fn distributed_single_instance_matches_mesh_split() {
        // world=1 distributed == local solve on the same grid (interior).
        use crate::backends::threads::ThreadsCommunicationManager;
        let n = 12;
        let iters = 3;
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let sys = system_for("coro");
        let run = run_distributed(
            &cmm,
            &sys,
            0,
            1,
            n,
            iters,
            (1, 2, 2),
            CommWaitMode::Blocking,
        )
        .unwrap();
        sys.shutdown().unwrap();
        let mut seq = Grid::new(n);
        let want = run_sequential(&mut seq, iters);
        assert!(
            (run.checksum - want).abs() < 1e-9,
            "{} != {want}",
            run.checksum
        );
    }
}
