//! Test Case 3 (paper §5.3): naive recursive Fibonacci as a fine-grained
//! task DAG — F(n-1) and F(n-2) as independent child tasks down to the
//! F(1)/F(0) leaves. Measures scheduling/context-switch overhead; the
//! computation itself is negligible.
//!
//! F(24) = 46368 requires exactly 150 049 tasks, matching the paper.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::core::error::Result;
use crate::frontends::tasking::{TaskCtx, TaskHandle, TaskSystem};

/// Number of tasks the naive recursion creates for F(n):
/// `T(n) = T(n-1) + T(n-2) + 1`, `T(0) = T(1) = 1` (= 2·F(n+1) − 1; the
/// top-level call is itself a task — T(24) = 150 049, as in the paper).
pub fn expected_tasks(n: u64) -> u64 {
    fn t(n: u64) -> u64 {
        if n < 2 {
            1
        } else {
            1 + t(n - 1) + t(n - 2)
        }
    }
    t(n)
}

/// Reference value (iterative).
pub fn fib_value(n: u64) -> u64 {
    let (mut a, mut b) = (0u64, 1u64);
    for _ in 0..n {
        let c = a + b;
        a = b;
        b = c;
    }
    a
}

fn fib_task(ctx: &TaskCtx, n: u64) -> u64 {
    if n < 2 {
        return n;
    }
    let left = Arc::new(AtomicU64::new(0));
    let right = Arc::new(AtomicU64::new(0));
    let (l2, r2) = (Arc::clone(&left), Arc::clone(&right));
    ctx.spawn("fib", move |c| {
        let v = fib_task(c, n - 1);
        // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
        l2.store(v, Ordering::Relaxed);
    });
    ctx.spawn("fib", move |c| {
        let v = fib_task(c, n - 2);
        // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
        r2.store(v, Ordering::Relaxed);
    });
    ctx.wait_children();
    left.load(Ordering::Relaxed) + right.load(Ordering::Relaxed)
}

/// Outcome of one Fibonacci run.
#[derive(Debug, Clone)]
pub struct FibonacciRun {
    /// Input `n`.
    pub n: u64,
    /// Computed `F(n)`.
    pub value: u64,
    /// Tasks this run executed.
    pub tasks_executed: u64,
    /// Wall-clock seconds.
    pub elapsed_s: f64,
}

/// Compute F(n) on `system`, returning the result and task count.
pub fn run(system: &TaskSystem, n: u64) -> Result<FibonacciRun> {
    let before = system.tasks_executed();
    let result = Arc::new(AtomicU64::new(0));
    let r = Arc::clone(&result);
    let t0 = std::time::Instant::now();
    system.run("fib-root", move |ctx| {
        let v = fib_task(ctx, n);
        // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
        r.store(v, Ordering::Relaxed);
    })?;
    let elapsed_s = t0.elapsed().as_secs_f64();
    Ok(FibonacciRun {
        n,
        // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
        value: result.load(Ordering::Relaxed),
        tasks_executed: system.tasks_executed() - before,
        elapsed_s,
    })
}

/// Build the Fibonacci computation as an explicit dependency DAG
/// (continuation style): each node's value task is gated by
/// `spawn_after` on its two subtree value tasks, instead of blocking in
/// `wait_children`. Returns the handle of the task that stores `F(n)`
/// into `out`.
fn build_fib_dag(ctx: &TaskCtx, n: u64, out: Arc<AtomicU64>) -> TaskHandle {
    if n < 2 {
        // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
        return ctx.spawn("fib-leaf", move |_| out.store(n, Ordering::Relaxed));
    }
    let left = Arc::new(AtomicU64::new(0));
    let right = Arc::new(AtomicU64::new(0));
    let lh = build_fib_dag(ctx, n - 1, Arc::clone(&left));
    let rh = build_fib_dag(ctx, n - 2, Arc::clone(&right));
    ctx.spawn_after(&[lh, rh], "fib-sum", move |_| {
        out.store(
            // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
            left.load(Ordering::Relaxed) + right.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
    })
}

/// Compute F(n) as a pure `spawn_after` DAG: no task ever blocks in
/// `wait_children` except the root, so the whole graph is visible to the
/// work-stealing scheduler up front (the continuation-passing shape
/// driven by the sched_scaling bench's `dag` series). Executes
/// `expected_tasks(n) + 1` tasks (the DAG plus the root): the top sum
/// task writes F(n) straight into the result cell.
pub fn run_dag(system: &TaskSystem, n: u64) -> Result<FibonacciRun> {
    let before = system.tasks_executed();
    let result = Arc::new(AtomicU64::new(0));
    let r = Arc::clone(&result);
    let t0 = std::time::Instant::now();
    system.run("fib-dag-root", move |ctx| {
        build_fib_dag(ctx, n, r);
        ctx.wait_children();
    })?;
    let elapsed_s = t0.elapsed().as_secs_f64();
    Ok(FibonacciRun {
        n,
        // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
        value: result.load(Ordering::Relaxed),
        tasks_executed: system.tasks_executed() - before,
        elapsed_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn system_for(backend: &str) -> Arc<TaskSystem> {
        let cm = crate::backends::registry()
            .builder()
            .compute(backend)
            .build()
            .unwrap()
            .compute()
            .unwrap();
        TaskSystem::new(cm, 4, false)
    }

    #[test]
    fn task_count_formula_matches_paper() {
        // The paper: F(24) requires 150 049 tasks in total.
        assert_eq!(expected_tasks(24), 150_049);
        assert_eq!(fib_value(24), 46_368);
    }

    #[test]
    fn coro_fib_correct_and_counts() {
        let sys = system_for("coro");
        let run = run(&sys, 12).unwrap();
        sys.shutdown().unwrap();
        assert_eq!(run.value, fib_value(12));
        assert_eq!(run.tasks_executed, expected_tasks(12));
    }

    #[test]
    fn nosv_fib_correct_and_counts() {
        let sys = system_for("nosv");
        let run = run(&sys, 10).unwrap();
        sys.shutdown().unwrap();
        assert_eq!(run.value, fib_value(10));
        assert_eq!(run.tasks_executed, expected_tasks(10));
    }

    #[test]
    fn dag_variant_matches_recursive_on_both_engines() {
        // The spawn_after DAG computes the same value with a predictable
        // task count: the DAG plus the root.
        for backend in ["coro", "threads"] {
            let sys = system_for(backend);
            let run = run_dag(&sys, 12).unwrap();
            sys.shutdown().unwrap();
            assert_eq!(run.value, fib_value(12), "{backend}");
            assert_eq!(run.tasks_executed, expected_tasks(12) + 1, "{backend}");
        }
    }
}
