//! Multi-instance inference serving (`hicr serve --np N`): the ROADMAP's
//! north-star composition as a runnable app. The root instance runs one
//! router shard of the serving frontend; every other instance is a
//! serving worker running continuous batching. The deployment/RPC mesh
//! is the control plane (membership, shutdown); the serving channel
//! rings are the data plane.
//!
//! Elasticity follows the two-phase protocol of DESIGN.md §7: the worker
//! *pool* is provisioned up front by `deploy`'s `ensure_world` ramp
//! (runtime spawn is impossible after the world's first barrier), and an
//! [`ElasticController`] activates/deactivates workers within the pool,
//! driven by the router's aggregate in-flight depth.
//!
//! The built-in closed-loop client submits `requests` verifiable
//! requests with a bounded in-flight window, counts typed [`Overloaded`]
//! rejections (retrying the logical request — closed-loop clients
//! experience backpressure as added latency, not loss), checks every
//! payload against [`expected_output`], and reports p50/p99 latency and
//! goodput.
//!
//! [`Overloaded`]: crate::frontends::serving::Overloaded

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::core::communication::CommunicationManager;
use crate::core::error::{HicrError, Result};
use crate::core::ids::MemorySpaceId;
use crate::core::instance::{InstanceManager, InstanceTemplate};
use crate::core::memory::LocalMemorySlot;
use crate::core::topology::TopologyRequirements;
use crate::frontends::collectives::ReduceOp;
use crate::frontends::deployment::{deploy, Deployment, DeploymentConfig};
use crate::frontends::serving::{
    build_mesh, payload_f32, ElasticController, RouterShard, ServingConfig, ServingNode,
    ServingRole, ServingWorker, WorkerStats, ST_OK,
};
use crate::runtime::batcher::BatchExecutor;
use crate::util::backoff::Backoff;

/// Closed-loop client + tier geometry for one serve run.
#[derive(Debug, Clone)]
pub struct ServeParams {
    /// Desired world size (1 router + N−1 workers), reached via the
    /// deploy-time `ensure_world` ramp.
    pub total: usize,
    /// Requests the built-in closed-loop client completes.
    pub requests: u64,
    /// Client in-flight window (closed-loop concurrency).
    pub window: usize,
    /// Elastic activation floor (workers initially active). The
    /// controller is engaged only when the pool has room to scale.
    pub min_active: usize,
    /// Engage the elastic controller at all.
    pub elastic: bool,
    pub cfg: ServingConfig,
}

impl Default for ServeParams {
    fn default() -> Self {
        Self {
            total: 3,
            requests: 512,
            window: 32,
            min_active: 1,
            elastic: true,
            cfg: ServingConfig::default(),
        }
    }
}

/// What the root observed (workers return `None`).
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// World size after the ramp-up.
    pub world: usize,
    /// Worker pool size.
    pub workers: usize,
    /// Requests completed (and payload-verified).
    pub requests: u64,
    /// Typed `Overloaded` rejections the closed-loop client absorbed.
    pub rejected: u64,
    /// Requests whose preferred worker was shed to a sibling.
    pub shed: u64,
    /// Completions whose payload failed verification (must be 0).
    pub checksum_failures: u64,
    /// Router-observed request latency percentiles, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Completed requests per second of serve-phase wall clock.
    pub goodput_rps: f64,
    /// Elastic activation events (scale-out, scale-in).
    pub scale_out_events: u64,
    pub scale_in_events: u64,
    /// Mesh-wide worker counters, tree-allreduced at teardown (the
    /// distributed reduction the ROADMAP names for the router's stats).
    pub mesh_requests: u64,
    pub mesh_responses: u64,
    pub mesh_malformed: u64,
    pub mesh_exec_errors: u64,
    /// Wall-clock seconds for this instance's whole run.
    pub elapsed_s: f64,
}

/// The app's verifiable model: out[j] = sum(inputs) × (j+1) per example
/// — cheap, deterministic, and sensitive to payload corruption, so the
/// router can check every completion against [`expected_output`].
pub fn reference_executor(input_dim: usize, output_dim: usize) -> BatchExecutor {
    Arc::new(move |input: &[f32]| {
        let examples = input.len() / input_dim;
        let mut out = vec![0f32; examples * output_dim];
        for e in 0..examples {
            let s: f32 = input[e * input_dim..(e + 1) * input_dim].iter().sum();
            for j in 0..output_dim {
                out[e * output_dim + j] = s * (j + 1) as f32;
            }
        }
        Ok(out)
    })
}

/// What [`reference_executor`] returns for `input` at output index `j`.
pub fn expected_output(input: &[f32], j: usize) -> f32 {
    input.iter().sum::<f32>() * (j + 1) as f32
}

/// Deterministic client input for request `i`.
pub fn request_input(i: u64, input_dim: usize) -> Vec<f32> {
    (0..input_dim)
        .map(|j| ((i % 97) as f32) + j as f32 * 0.5)
        .collect()
}

/// Run this instance's side of the serving tier. Collective across the
/// world: the root returns `Some(report)`, workers serve until shutdown
/// and return `None`. `topology_json` is this instance's serialized
/// device tree (for the deployment mesh's topology RPC).
pub fn run(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    topology_json: String,
    params: &ServeParams,
) -> Result<Option<ServeReport>> {
    let t0 = Instant::now();
    let alloc = |len| LocalMemorySlot::alloc(MemorySpaceId(1), len);
    let template = InstanceTemplate::new(TopologyRequirements::default());
    let mut d = deploy(
        im,
        cmm,
        params.total,
        &template,
        &DeploymentConfig::default(),
        topology_json,
        alloc,
    )?;
    let shards = vec![d.root];
    let workers = d.workers();
    if workers.is_empty() {
        return Err(HicrError::Instance(
            "serving needs at least one worker (launch with --np 2 or more)".into(),
        ));
    }
    // Tree overlay for the teardown stats allreduce — built here, at the
    // same program point on every member (collective bring-up), and wired
    // to the deployment quarantine so a dead rank is a typed error.
    let mut coll = d.collectives(Arc::clone(cmm), 0x5E, 4096, alloc)?;

    if !d.is_root {
        let node = build_mesh(
            cmm,
            ServingRole::Worker { rank: d.me },
            &shards,
            &workers,
            &params.cfg,
            alloc,
            Some(reference_executor(params.cfg.input_dim, params.cfg.output_dim)),
        )?;
        let ServingNode::Worker(worker) = node else {
            return Err(HicrError::InvalidState(
                "worker role resolved to a non-worker node".into(),
            ));
        };
        let wstats = worker_loop(&mut d, worker)?;
        // Fold this worker's counters into the mesh totals (the root
        // contributes zeros); every member learns the same sums.
        coll.allreduce(
            &[
                wstats.requests as f64,
                wstats.responses as f64,
                wstats.malformed as f64,
                wstats.exec_errors as f64,
            ],
            ReduceOp::Sum,
        )?;
        // Exit in lockstep with the root's post-shutdown barrier.
        im.barrier()?;
        return Ok(None);
    }

    let node = build_mesh(
        cmm,
        ServingRole::Router { shard: d.root },
        &shards,
        &workers,
        &params.cfg,
        alloc,
        None,
    )?;
    let ServingNode::Router(mut router) = node else {
        return Err(HicrError::InvalidState(
            "router role resolved to a non-router node".into(),
        ));
    };
    let elastic = if params.elastic
        && workers.len() > 1
        && params.cfg.high_watermark >= 2
        && params.min_active < workers.len()
    {
        let ctl = ElasticController::new(
            1,
            workers.len(),
            params.min_active.max(1),
            params.cfg.high_watermark,
            (params.cfg.high_watermark / 4).max(1),
        )?;
        router.set_elastic(Arc::clone(&ctl), 0);
        Some(ctl)
    } else {
        None
    };

    match closed_loop(&mut router, params) {
        Ok(client) => {
            d.shutdown_workers()?;
            // Workers enter the stats allreduce once released from their
            // serve loops; the root contributes zeros and reads the sums.
            let mesh = coll.allreduce(&[0.0; 4], ReduceOp::Sum)?;
            im.barrier()?;
            let rs = router.stats();
            let (scale_out_events, scale_in_events) = elastic
                .map(|c| c.scale_events())
                .unwrap_or((0, 0));
            Ok(Some(ServeReport {
                world: d.ranks.len(),
                workers: workers.len(),
                requests: client.completed,
                rejected: rs.rejected,
                shed: rs.shed,
                checksum_failures: client.checksum_failures,
                p50_ms: client.p50_s * 1e3,
                p99_ms: client.p99_s * 1e3,
                goodput_rps: client.goodput_rps,
                scale_out_events,
                scale_in_events,
                mesh_requests: mesh[0] as u64,
                mesh_responses: mesh[1] as u64,
                mesh_malformed: mesh[2] as u64,
                mesh_exec_errors: mesh[3] as u64,
                elapsed_s: t0.elapsed().as_secs_f64(),
            }))
        }
        Err(e) => {
            // Best-effort release so live workers do not sit in their
            // serve loops forever while the launcher reports the error.
            // The shutdown calls carry the RPC deadline, so a dead
            // worker surfaces as a typed Timeout/PeerLost — and a failed
            // release is reported alongside the primary error instead of
            // being silently swallowed.
            match d.shutdown_workers() {
                Ok(()) => {
                    // Released workers still enter the stats allreduce;
                    // join it best-effort so they are not left waiting
                    // out their collective deadline.
                    let _ = coll.allreduce(&[0.0; 4], ReduceOp::Sum);
                    let _ = im.barrier();
                    Err(e)
                }
                Err(shut) => Err(HicrError::Instance(format!(
                    "serving tier failed: {e}; releasing the workers \
                     also failed: {shut}"
                ))),
            }
        }
    }
}

/// Worker side: interleave the RPC control plane (so the shutdown call
/// is observed) with the serving data plane, then drain the batcher.
/// Returns the final worker counters (for the mesh stats allreduce).
fn worker_loop(d: &mut Deployment, mut worker: ServingWorker) -> Result<WorkerStats> {
    let mut backoff = Backoff::new();
    loop {
        let served = d.mesh.server.try_serve_one()?;
        let moved = worker.pump()?;
        if d.shutdown_requested() {
            break;
        }
        if !served && moved == 0 {
            backoff.wait();
        } else {
            backoff.reset();
        }
    }
    worker.shutdown()
}

struct ClientOutcome {
    completed: u64,
    checksum_failures: u64,
    p50_s: f64,
    p99_s: f64,
    goodput_rps: f64,
}

/// The built-in closed-loop client: `window` requests in flight, every
/// completion payload-verified, rejections retried (the rejected state
/// is visible in the router stats).
fn closed_loop(router: &mut RouterShard, params: &ServeParams) -> Result<ClientOutcome> {
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(params.requests as usize);
    let mut expected: HashMap<u64, f32> = HashMap::new();
    let mut checksum_failures = 0u64;
    let mut in_flight = 0usize;
    let mut submitted = 0u64;
    let mut completed = 0u64;
    let mut backoff = Backoff::new();
    while completed < params.requests {
        let mut progressed = false;
        while in_flight < params.window && submitted < params.requests {
            let input = request_input(submitted, params.cfg.input_dim);
            match router.try_submit(&input)? {
                Ok(id) => {
                    expected.insert(id, expected_output(&input, 0));
                    in_flight += 1;
                    submitted += 1;
                    progressed = true;
                }
                Err(_overloaded) => break, // absorb backpressure; retry after a drain
            }
        }
        router.flush()?;
        let n = router.drain(|done| {
            latencies.push(done.latency.as_secs_f64());
            let want = expected.get(&done.req_id).copied();
            let ok = done.status == ST_OK
                && want.is_some_and(|w| payload_f32(done.payload, 0) == w);
            if !ok {
                checksum_failures += 1;
            }
        })?;
        in_flight -= n as usize;
        completed += n;
        if n > 0 || progressed {
            backoff.reset();
        } else {
            backoff.wait();
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let summary = crate::util::stats::Summary::of(&latencies)
        .ok_or_else(|| HicrError::InvalidState("no latency samples".into()))?;
    Ok(ClientOutcome {
        completed,
        checksum_failures,
        p50_s: summary.p50,
        p99_s: summary.p99,
        goodput_rps: completed as f64 / elapsed.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::threads::ThreadsCommunicationManager;
    use crate::core::instance::testworld::local_world;
    use crate::core::topology::Topology;

    #[test]
    fn request_inputs_are_deterministic_and_verifiable() {
        let a = request_input(7, 8);
        let b = request_input(7, 8);
        assert_eq!(a, b);
        let exec = reference_executor(8, 2);
        let out = exec(&a).unwrap();
        assert_eq!(out[0], expected_output(&a, 0));
        assert_eq!(out[1], expected_output(&a, 1));
    }

    /// Full serve tier over the in-process threads world: 1 router +
    /// 2 workers, closed-loop client, verified payloads, elastic
    /// controller engaged.
    #[test]
    fn serve_roundtrip_threads_world() {
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let params = ServeParams {
            total: 3,
            requests: 96,
            window: 8,
            ..ServeParams::default()
        };
        let mut handles = Vec::new();
        for im in local_world(3) {
            let cmm = Arc::clone(&cmm);
            let params = params.clone();
            handles.push(std::thread::spawn(move || {
                run(&im, &cmm, Topology::default().serialize(), &params)
            }));
        }
        let mut reports = Vec::new();
        for h in handles {
            if let Some(r) = h.join().unwrap().unwrap() {
                reports.push(r);
            }
        }
        assert_eq!(reports.len(), 1, "exactly the root reports");
        let r = &reports[0];
        assert_eq!(r.world, 3);
        assert_eq!(r.workers, 2);
        assert_eq!(r.requests, 96);
        assert_eq!(r.checksum_failures, 0);
        assert!(r.goodput_rps > 0.0);
        assert!(r.p50_ms >= 0.0 && r.p99_ms >= r.p50_ms);
        // The allreduced mesh totals must account for every completed
        // request: each was ingested and answered by exactly one worker.
        assert_eq!(r.mesh_requests, 96);
        assert_eq!(r.mesh_responses, 96);
        assert_eq!(r.mesh_malformed, 0);
        assert_eq!(r.mesh_exec_errors, 0);
    }
}
