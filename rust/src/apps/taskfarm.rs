//! Master/worker task farm over the deployment mesh (the paper's Fig. 7
//! orchestration pattern as a runnable distributed app).
//!
//! Every instance enters [`run`] (or [`run_spill`]): the root ensures
//! the world holds `total` instances (spawning the difference at runtime
//! through the instance manager — the elastic ramp-up), all instances
//! join the deployment mesh, workers register the farmed function and
//! serve, while the root gathers all worker topologies via the built-in
//! `topology` RPC, dispatches `tasks` tasks, verifies every result, and
//! shuts the farm down by RPC.
//!
//! [`run_spill`] is the **distributed spill** variant: the root executes
//! tasks on its own local [`TaskSystem`] and, whenever the local
//! scheduler's ready backlog saturates ([`TaskSystem::ready_backlog`]
//! reaches [`SpillPolicy::backlog_threshold`]), offloads the overflow —
//! closures identified by RPC fn-id, arguments on the wire — round-robin
//! to idle instances over the PR 4 RPC mesh. Work stealing across
//! *instances*, not just threads: the same saturation signal that makes
//! an idle thread steal from a loaded deque makes a loaded instance push
//! to an idle one. Spilled calls are currently **stop-and-wait** — each
//! offload is one synchronous round-trip (the RPC link carries one
//! outstanding call), so remote throughput is 1/RTT while the local
//! lane drains concurrently; pipelined multi-link dispatch is future
//! work (DESIGN.md §5).
//!
//! [`run_steal`] is the **pull-based** variant (PR 7), the default farm
//! mode: the root seeds every task on its own remote-ready lane and
//! idle instances *steal* them over the mesh — topology-ordered victim
//! selection, steal-half batches, task payloads moving lazily only when
//! the thief dispatches (DESIGN.md §8). `run_spill` survives as the
//! push-only ablation the steal benches compare against.
//!
//! [`run_steal_chaos`] is the **fault-injected** farm (PR 8): a chosen
//! worker crashes mid-drain and the root's supervised drain must
//! detect it, quarantine it, replay its stolen descriptors from the
//! crash ledger, and still verify every splitmix result (DESIGN.md §9).
//!
//! Written purely against the abstract managers and the deployment/RPC
//! frontends: the same code farms over the threads backend (in-process)
//! and over mpisim (real processes launched by `hicr launch`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::core::communication::CommunicationManager;
use crate::core::error::{HicrError, Result};
use crate::core::ids::MemorySpaceId;
use crate::core::instance::{InstanceManager, InstanceTemplate};
use crate::core::memory::LocalMemorySlot;
use crate::core::topology::{Topology, TopologyRequirements};
use crate::frontends::deployment::{deploy, Deployment, DeploymentConfig};
use crate::frontends::tasking::{StealConfig, StealPool, StealTopology, TaskSystem};

/// The farmed RPC.
pub const FN_TASK: &str = "taskfarm/execute";

/// The steal-mode task body (registered on every instance's
/// [`StealPool`], the RPC-farm idiom lifted to descriptor tasks).
pub const FN_STEAL_TASK: &str = "taskfarm/steal-task";

/// Argument blob size of a steal-mode task: an 8-byte index plus 88
/// bytes of index-derived filler — deliberately above the default
/// [`StealConfig::lazy_threshold`], so every stolen task exercises the
/// lazy payload path.
pub const STEAL_ARGS_LEN: usize = 96;

/// Build the argument blob for steal-mode task `i`.
pub fn steal_args(i: u64) -> Vec<u8> {
    let mut args = i.to_le_bytes().to_vec();
    args.extend((0..STEAL_ARGS_LEN - 8).map(|j| (i as u8).wrapping_add(j as u8)));
    args
}

/// The steal-mode task body: verify the filler byte-for-byte (payload
/// corruption in flight cannot hide) and return the splitmix value.
fn steal_body(args: &[u8]) -> Result<Vec<u8>> {
    if args.len() != STEAL_ARGS_LEN {
        return Err(HicrError::Bounds(format!(
            "steal task payload must be {STEAL_ARGS_LEN} B, got {}",
            args.len()
        )));
    }
    let x = u64::from_le_bytes(args[0..8].try_into().unwrap());
    for (j, &b) in args[8..].iter().enumerate() {
        let want = (x as u8).wrapping_add(j as u8);
        if b != want {
            return Err(HicrError::InvalidState(format!(
                "task {x}: filler byte {j} is {b:#04x}, want {want:#04x}"
            )));
        }
    }
    Ok(task_value(x).to_le_bytes().to_vec())
}

/// The task kernel: a splitmix64 avalanche of the task index — cheap,
/// deterministic, and sensitive to any payload corruption, so the root
/// can verify every single result.
pub fn task_value(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fault-injection mode of the taskfarm app (the `--chaos` CLI flag).
/// Only meaningful over a multi-process backend (mpisim): the injected
/// "crash" is a real `process::exit`, which in an in-process world
/// would take the whole harness down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosMode {
    /// The highest-rank worker kills its own process — no goodbye, no
    /// teardown — immediately after its first successful steal: mid-
    /// drain, provably holding stolen descriptors it has not yet
    /// dispatched. The surviving mesh must detect the abnormal
    /// departure, re-enqueue the victim's descriptors from the crash
    /// ledger, and still complete every task with a correct splitmix
    /// result (DESIGN.md §9 acceptance scenario).
    KillOne,
}

impl ChaosMode {
    /// Parse the CLI spelling of a chaos mode (`--chaos kill-one`).
    pub fn parse(s: &str) -> Result<ChaosMode> {
        match s {
            "kill-one" => Ok(ChaosMode::KillOne),
            other => Err(HicrError::Rejected(format!(
                "unknown chaos mode '{other}' (expected: kill-one)"
            ))),
        }
    }
}

/// When the root offloads work to remote instances instead of running
/// it on its local task system.
#[derive(Debug, Clone, Copy)]
pub struct SpillPolicy {
    /// Spill a task to a remote worker when the local scheduler's ready
    /// backlog is at least this deep. `0` spills everything (the pure
    /// remote farm); `usize::MAX` keeps everything local.
    pub backlog_threshold: usize,
}

impl Default for SpillPolicy {
    fn default() -> Self {
        Self {
            backlog_threshold: 8,
        }
    }
}

/// What the root observed (workers return `None`).
#[derive(Debug, Clone)]
pub struct FarmReport {
    /// World size after the elastic ramp-up.
    pub world: usize,
    /// Remote workers serving the farmed RPC.
    pub workers: usize,
    /// Total tasks dispatched (local + spilled).
    pub tasks: u64,
    /// Tasks executed per worker rank (spilled work only).
    pub per_worker: Vec<(u32, u64)>,
    /// Wrapping sum of all verified results.
    pub checksum: u64,
    /// Tasks the root executed on its local task system.
    pub local_tasks: u64,
    /// Tasks offloaded over the RPC mesh (push-based spill mode only).
    pub spilled_tasks: u64,
    /// Tasks pulled off the root's lane by thieves (steal mode only).
    pub stolen_tasks: u64,
    /// Descriptors re-enqueued after a holder crashed — crash-ledger
    /// replays plus payload-lost re-spawns (steal mode only; the
    /// `recovered=` figure of the CLI summary, asserted by the chaos
    /// launch smoke).
    pub recovered: u64,
    /// Steal RPCs the root's own pool issued (it too escalates to
    /// stealing when its lane runs dry).
    pub steal_rpcs_attempted: u64,
    /// Root-issued steal RPCs that returned at least one task.
    pub steal_rpcs_succeeded: u64,
    /// Argument bytes the root parked for lazy transfer to thieves.
    pub lazy_payload_bytes: u64,
    /// Worker topologies gathered through the built-in RPC.
    pub gathered_topologies: usize,
    /// Devices across all gathered topologies.
    pub total_devices: usize,
    /// Wall-clock seconds for this instance's side of the farm.
    pub elapsed_s: f64,
}

/// Run this instance's side of the pure remote farm (every task goes
/// over the RPC mesh). Collective across the world: root returns
/// `Some(report)`, workers serve until shutdown and return `None`.
/// `topology_json` is this instance's serialized device tree.
pub fn run(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    topology_json: String,
    total: usize,
    tasks: u64,
) -> Result<Option<FarmReport>> {
    run_spill(im, cmm, topology_json, total, tasks, None)
}

/// [`run`] with a local execution lane: the root runs tasks on `local`'s
/// task system and spills to remote instances only when the local ready
/// backlog saturates per the [`SpillPolicy`]. Passing `None` (or a
/// threshold of 0 with workers present) degenerates to the pure remote
/// farm. Workers ignore `local`.
pub fn run_spill(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    topology_json: String,
    total: usize,
    tasks: u64,
    local: Option<(&TaskSystem, SpillPolicy)>,
) -> Result<Option<FarmReport>> {
    let t0 = Instant::now();
    let alloc = |len| LocalMemorySlot::alloc(MemorySpaceId(1), len);
    let template = InstanceTemplate::new(TopologyRequirements::default());
    let mut d = deploy(
        im,
        cmm,
        total,
        &template,
        &DeploymentConfig::default(),
        topology_json,
        alloc,
    )?;

    if !d.is_root {
        d.mesh.server.register(FN_TASK, |args| {
            let x = u64::from_le_bytes(args.try_into().map_err(|_| {
                HicrError::Bounds("taskfarm payload must be 8 B".into())
            })?);
            Ok(task_value(x).to_le_bytes().to_vec())
        })?;
        d.serve_until_shutdown()?;
        // Exit in lockstep with the root's post-shutdown barrier.
        im.barrier()?;
        return Ok(None);
    }

    match orchestrate(&mut d, tasks, local) {
        Ok((topos, total_devices, per_worker, checksum, local_tasks)) => {
            d.shutdown_workers()?;
            im.barrier()?;
            Ok(Some(FarmReport {
                world: d.ranks.len(),
                workers: d.workers().len(),
                tasks,
                per_worker: per_worker.into_iter().collect(),
                checksum,
                local_tasks,
                spilled_tasks: tasks - local_tasks,
                stolen_tasks: 0,
                recovered: 0,
                steal_rpcs_attempted: 0,
                steal_rpcs_succeeded: 0,
                lazy_payload_bytes: 0,
                gathered_topologies: topos.len(),
                total_devices,
                elapsed_s: t0.elapsed().as_secs_f64(),
            }))
        }
        Err(e) => {
            // Best-effort release: without this, live workers would sit
            // in their serve loops forever and the launcher would hang
            // instead of reporting the orchestration error. The shutdown
            // calls carry the RPC deadline, so a worker that died
            // mid-farm surfaces as a typed Timeout/PeerLost here instead
            // of stalling — and a failed release is reported alongside
            // the primary error, never silently swallowed.
            match d.shutdown_workers() {
                Ok(()) => {
                    let _ = im.barrier();
                    Err(e)
                }
                Err(shut) => Err(HicrError::Instance(format!(
                    "taskfarm orchestration failed: {e}; releasing the \
                     workers also failed: {shut}"
                ))),
            }
        }
    }
}

type Orchestrated = (Vec<(u32, Topology)>, usize, BTreeMap<u32, u64>, u64, u64);

/// The root's orchestration body, separated so `run` can release the
/// workers on *any* error path. Dispatches every task either onto the
/// local task system (when one is provided and its backlog is below the
/// spill threshold) or over the RPC mesh, then verifies every result.
fn orchestrate(
    d: &mut Deployment,
    tasks: u64,
    local: Option<(&TaskSystem, SpillPolicy)>,
) -> Result<Orchestrated> {
    let topos = d.gather_topologies()?;
    let total_devices = topos.iter().map(|(_, t)| t.devices.len()).sum();
    let workers = d.workers();
    if workers.is_empty() && local.is_none() {
        return Err(HicrError::Instance(
            "taskfarm needs at least one worker (launch with --np 2 or more) \
             or a local task system to spill from"
                .into(),
        ));
    }
    let mut per_worker: BTreeMap<u32, u64> =
        workers.iter().map(|&w| (w, 0)).collect();
    let mut checksum = 0u64;
    let mut local_results: Vec<(u64, Arc<AtomicU64>)> = Vec::new();
    let mut next_remote = 0usize;
    for i in 0..tasks {
        let spill = !workers.is_empty()
            && match local {
                None => true,
                Some((sys, policy)) => sys.ready_backlog() >= policy.backlog_threshold,
            };
        if spill {
            let w = workers[next_remote % workers.len()];
            next_remote += 1;
            let ret = d.client(w)?.call(FN_TASK, &i.to_le_bytes())?;
            let got =
                u64::from_le_bytes(ret.as_slice().try_into().map_err(|_| {
                    HicrError::Transport(format!(
                        "task {i}: short response ({} B) from worker {w}",
                        ret.len()
                    ))
                })?);
            let want = task_value(i);
            if got != want {
                return Err(HicrError::InvalidState(format!(
                    "task {i} on worker {w}: got {got:#018x}, want {want:#018x}"
                )));
            }
            checksum = checksum.wrapping_add(got);
            *per_worker.get_mut(&w).expect("dispatched to a known worker") += 1;
        } else {
            let (sys, _) = local.expect("spill=false implies a local system");
            let cell = Arc::new(AtomicU64::new(0));
            let out = Arc::clone(&cell);
            sys.submit("farm-local", move |_| {
                // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
                out.store(task_value(i), Ordering::Relaxed);
            });
            local_results.push((i, cell));
        }
    }
    if let Some((sys, _)) = local {
        sys.wait_idle()?;
        for (i, cell) in &local_results {
            // relaxed-ok: result cell; the task-system join (wait_children/wait_idle) orders this against the worker
            let (got, want) = (cell.load(Ordering::Relaxed), task_value(*i));
            if got != want {
                return Err(HicrError::InvalidState(format!(
                    "local task {i}: got {got:#018x}, want {want:#018x}"
                )));
            }
            checksum = checksum.wrapping_add(got);
        }
    }
    let local_tasks = local_results.len() as u64;
    Ok((topos, total_devices, per_worker, checksum, local_tasks))
}

/// The **pull-based** farm (PR 7, subsuming [`run_spill`] as the push
/// ablation): every instance fronts its local task system with a
/// [`StealPool`]; the root seeds *all* tasks on its own remote-ready
/// lane and idle instances steal them over the mesh — victim selection
/// in topology order, payloads moving lazily. Collective across the
/// world: the root returns `Some(report)`, workers drive their pools
/// until the root's shutdown RPC and return `None`.
///
/// `sys` is this instance's local execution engine (every rank executes
/// in steal mode, so every rank brings one); `host_of` maps each rank
/// to an opaque host key for [`StealTopology`] — pass `|_| 0` for
/// single-host deployments.
pub fn run_steal(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    topology_json: String,
    total: usize,
    tasks: u64,
    sys: Arc<TaskSystem>,
    config: StealConfig,
    host_of: impl Fn(u32) -> u64,
) -> Result<Option<FarmReport>> {
    run_steal_chaos(
        im, cmm, topology_json, total, tasks, sys, config, host_of, None,
    )
}

/// [`run_steal`] with optional fault injection: under
/// [`ChaosMode::KillOne`] the highest-rank worker crashes its own
/// process mid-drain, and the farm must still complete — the root's
/// supervised drain polls the backend's failure detector between drive
/// rounds ([`crate::frontends::deployment::Supervisor`]), quarantines
/// the dead rank, replays its stolen descriptors from the crash ledger,
/// and reports the count in [`FarmReport::recovered`]. With `chaos =
/// None` this *is* `run_steal` (supervision still runs; on backends
/// without a failure detector it is a no-op).
#[allow(clippy::too_many_arguments)]
pub fn run_steal_chaos(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    topology_json: String,
    total: usize,
    tasks: u64,
    sys: Arc<TaskSystem>,
    config: StealConfig,
    host_of: impl Fn(u32) -> u64,
    chaos: Option<ChaosMode>,
) -> Result<Option<FarmReport>> {
    let t0 = Instant::now();
    let alloc = |len| LocalMemorySlot::alloc(MemorySpaceId(1), len);
    let template = InstanceTemplate::new(TopologyRequirements::default());
    let mut d = deploy(
        im,
        cmm,
        total,
        &template,
        &DeploymentConfig::default(),
        topology_json,
        alloc,
    )?;
    let topo = StealTopology {
        me: d.me,
        hosts: d.ranks.iter().map(|&r| (r, host_of(r))).collect(),
    };
    let pool = StealPool::new(sys, &topo, config);
    pool.register(FN_STEAL_TASK, steal_body)?;
    pool.install(&mut d.mesh.server)?;

    if !d.is_root {
        // Drive the pool — dispatching stolen work locally, serving
        // peers, escalating to steals — until the root's shutdown RPC
        // flips the flag (served by our own drive loop). The flag is the
        // cancel signal too, so a shutdown observed mid-steal aborts the
        // wait instead of hanging on an already-departed victim. Each
        // round also polls the backend's failure detector, so a crashed
        // sibling is quarantined (no more steal probes at it) instead of
        // timed out against.
        let flag = d.shutdown_signal();
        let mut sup = d.supervisor();
        let chaos_victim = chaos == Some(ChaosMode::KillOne)
            && d.workers().into_iter().max() == Some(d.me);
        pool.drive_while(&mut d.mesh, || {
            if chaos_victim && pool.sched_stats().tasks_migrated_in > 0 {
                // Injected crash: die *now*, holding stolen descriptors
                // we have not dispatched — no goodbye frame, no
                // destructors (`process::exit` skips Drop), so the hub
                // observes an abnormal departure and the root must
                // recover the work from its crash ledger (DESIGN.md §9).
                // Status 0 because the *launcher* should still count
                // this child as clean: the crash is between the instance
                // and the hub, not between the process and its parent.
                std::process::exit(0);
            }
            if let Ok(events) = sup.poll(im) {
                for e in events {
                    pool.note_peer_lost(e.rank);
                }
            }
            !flag.load(Ordering::Acquire)
        })?;
        im.barrier()?;
        return Ok(None);
    }

    let orchestrated = (|| -> Result<(Vec<(u32, Topology)>, usize, u64)> {
        // Seed the whole workload on the root's lane *before* gathering
        // topologies: thieves start probing the moment they deploy, and
        // the gather round-trips give their first steals a full lane.
        let mut ids = Vec::with_capacity(tasks as usize);
        for i in 0..tasks {
            ids.push((i, pool.spawn(FN_STEAL_TASK, steal_args(i))?));
        }
        let topos = d.gather_topologies()?;
        let total_devices = topos.iter().map(|(_, t)| t.devices.len()).sum();
        // Supervised drain: between drive rounds, poll the backend's
        // failure detector. A dead thief's stolen descriptors re-enter
        // the lane (crash-ledger replay in the pool), and the drain
        // predicate then naturally waits for their re-execution too —
        // produce-once task keys make the replay safe (DESIGN.md §9).
        let mut sup = d.supervisor();
        pool.drive_while(&mut d.mesh, || {
            if let Ok(events) = sup.poll(im) {
                for e in events {
                    pool.note_peer_lost(e.rank);
                }
            }
            !pool.drained()
        })?;
        let mut checksum = 0u64;
        for (i, id) in ids {
            let got = pool.take_result(id)?.ok_or_else(|| {
                HicrError::InvalidState(format!("task {i} lost after drain"))
            })?;
            let got = u64::from_le_bytes(got.as_slice().try_into().map_err(
                |_| {
                    HicrError::Transport(format!(
                        "task {i}: short result ({} B)",
                        got.len()
                    ))
                },
            )?);
            let want = task_value(i);
            if got != want {
                return Err(HicrError::InvalidState(format!(
                    "task {i}: got {got:#018x}, want {want:#018x}"
                )));
            }
            checksum = checksum.wrapping_add(got);
        }
        Ok((topos, total_devices, checksum))
    })();

    match orchestrated {
        Ok((topos, total_devices, checksum)) => {
            // Quarantine dead workers on the mesh before the release
            // round: their clients fast-fail with PeerLost and the
            // shutdown fan-out skips them instead of timing out.
            for r in d.lost_ranks() {
                d.note_worker_lost(r);
            }
            // Pumped shutdown: thieves may still be probing our lane, so
            // the root keeps answering (empty batches) while the
            // shutdown calls are in flight.
            d.shutdown_workers_pumped()?;
            im.barrier()?;
            let stats = pool.sched_stats();
            let mut local_tasks = 0u64;
            let mut stolen_tasks = 0u64;
            let mut per_worker = Vec::new();
            for (rank, count) in pool.completed_by() {
                if rank == d.me {
                    local_tasks = count;
                } else {
                    stolen_tasks += count;
                    per_worker.push((rank, count));
                }
            }
            Ok(Some(FarmReport {
                world: d.ranks.len(),
                workers: d.workers().len(),
                tasks,
                per_worker,
                checksum,
                local_tasks,
                spilled_tasks: 0,
                stolen_tasks,
                recovered: stats.tasks_recovered,
                steal_rpcs_attempted: stats.remote_steal_attempts,
                steal_rpcs_succeeded: stats.remote_steals,
                lazy_payload_bytes: stats.lazy_payload_bytes,
                gathered_topologies: topos.len(),
                total_devices,
                elapsed_s: t0.elapsed().as_secs_f64(),
            }))
        }
        Err(e) => {
            // Same best-effort release as run_spill — quarantine known
            // casualties first so the fan-out skips them, surface (never
            // swallow) a secondary release failure, and keep the
            // orchestration error as the primary result when the release
            // itself succeeds.
            for r in d.lost_ranks() {
                d.note_worker_lost(r);
            }
            match d.shutdown_workers_pumped() {
                Ok(()) => {
                    let _ = im.barrier();
                    Err(e)
                }
                Err(shut) => Err(HicrError::Instance(format!(
                    "taskfarm orchestration failed: {e}; releasing the \
                     workers also failed: {shut}"
                ))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::threads::ThreadsCommunicationManager;
    use crate::core::instance::testworld::local_world;

    #[test]
    fn task_value_deterministic_and_mixing() {
        assert_eq!(task_value(7), task_value(7));
        assert_ne!(task_value(7), task_value(8));
        assert_ne!(task_value(0), 0);
    }

    /// Full farm over the threads backend: 1 root + 2 workers in one
    /// process, 31 tasks (odd count → uneven round-robin) all verified.
    #[test]
    fn farm_in_process_three_instances() {
        let n = 3usize;
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let mut joins = Vec::new();
        for im in local_world(n) {
            let cmm = Arc::clone(&cmm);
            joins.push(std::thread::spawn(move || {
                run(&im, &cmm, Topology::default().serialize(), n, 31).unwrap()
            }));
        }
        let reports: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        let report = reports
            .iter()
            .flatten()
            .next()
            .expect("root produced a report");
        assert_eq!(report.world, 3);
        assert_eq!(report.workers, 2);
        assert_eq!(report.tasks, 31);
        assert_eq!(report.gathered_topologies, 2);
        let per: u64 = report.per_worker.iter().map(|(_, c)| c).sum();
        assert_eq!(per, 31);
        assert_eq!(report.per_worker[0].1, 16); // rank 1 gets the extra task
        assert_eq!(report.per_worker[1].1, 15);
        let want: u64 = (0..31).map(task_value).fold(0, u64::wrapping_add);
        assert_eq!(report.checksum, want);
        // The pure remote farm spills everything.
        assert_eq!(report.local_tasks, 0);
        assert_eq!(report.spilled_tasks, 31);
    }

    /// Drive the spill farm with a given policy on the root and return
    /// the root's report.
    fn spill_farm(tasks: u64, policy: SpillPolicy) -> FarmReport {
        let n = 3usize;
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let mut joins = Vec::new();
        for im in local_world(n) {
            let cmm = Arc::clone(&cmm);
            joins.push(std::thread::spawn(move || {
                if im.is_root() {
                    let cm = crate::backends::registry()
                        .builder()
                        .compute("threads")
                        .build()
                        .unwrap()
                        .compute()
                        .unwrap();
                    let sys = TaskSystem::new(cm, 2, false);
                    let report = run_spill(
                        &im,
                        &cmm,
                        Topology::default().serialize(),
                        n,
                        tasks,
                        Some((sys.as_ref(), policy)),
                    )
                    .unwrap();
                    sys.shutdown().unwrap();
                    report
                } else {
                    run_spill(&im, &cmm, Topology::default().serialize(), n, tasks, None)
                        .unwrap();
                    None
                }
            }));
        }
        joins
            .into_iter()
            .filter_map(|j| j.join().unwrap())
            .next()
            .expect("root produced a report")
    }

    #[test]
    fn spill_farm_all_local_when_threshold_unreachable() {
        let report = spill_farm(24, SpillPolicy {
            backlog_threshold: usize::MAX,
        });
        assert_eq!(report.local_tasks, 24);
        assert_eq!(report.spilled_tasks, 0);
        let want: u64 = (0..24).map(task_value).fold(0, u64::wrapping_add);
        assert_eq!(report.checksum, want);
    }

    #[test]
    fn spill_farm_all_remote_at_zero_threshold() {
        let report = spill_farm(24, SpillPolicy {
            backlog_threshold: 0,
        });
        assert_eq!(report.local_tasks, 0);
        assert_eq!(report.spilled_tasks, 24);
        let per: u64 = report.per_worker.iter().map(|(_, c)| c).sum();
        assert_eq!(per, 24);
        let want: u64 = (0..24).map(task_value).fold(0, u64::wrapping_add);
        assert_eq!(report.checksum, want);
    }

    #[test]
    fn spill_farm_mixed_accounts_every_task() {
        // With a small threshold the split is timing-dependent, but the
        // accounting and the verified checksum must be exact.
        let report = spill_farm(64, SpillPolicy {
            backlog_threshold: 2,
        });
        assert_eq!(report.local_tasks + report.spilled_tasks, 64);
        let remote: u64 = report.per_worker.iter().map(|(_, c)| c).sum();
        assert_eq!(remote, report.spilled_tasks);
        let want: u64 = (0..64).map(task_value).fold(0, u64::wrapping_add);
        assert_eq!(report.checksum, want);
        // Push-mode reports carry no steal telemetry.
        assert_eq!(report.stolen_tasks, 0);
        assert_eq!(report.steal_rpcs_attempted, 0);
        assert_eq!(report.lazy_payload_bytes, 0);
    }

    #[test]
    fn steal_args_roundtrip_through_body() {
        let got = steal_body(&steal_args(17)).unwrap();
        assert_eq!(
            u64::from_le_bytes(got.try_into().unwrap()),
            task_value(17)
        );
        // Corruption anywhere in the filler is caught, not silently run.
        let mut bad = steal_args(17);
        bad[50] ^= 0xFF;
        assert!(steal_body(&bad).is_err());
        assert!(steal_body(&steal_args(17)[..8]).is_err());
    }

    fn task_system() -> Arc<TaskSystem> {
        let cm = crate::backends::registry()
            .builder()
            .compute("threads")
            .build()
            .unwrap()
            .compute()
            .unwrap();
        TaskSystem::new(cm, 2, false)
    }

    /// The tentpole acceptance test: a 4-instance world where EVERY task
    /// is seeded on the root. Pull-based stealing must drain the
    /// imbalance with zero lost or duplicated tasks (the splitmix
    /// checksum covers both), remote ranks must actually execute work,
    /// and the over-threshold payloads must move lazily.
    #[test]
    fn steal_farm_drains_all_on_root_imbalance() {
        let n = 4usize;
        let tasks = 60u64;
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let mut joins = Vec::new();
        for im in local_world(n) {
            let cmm = Arc::clone(&cmm);
            joins.push(std::thread::spawn(move || {
                let sys = task_system();
                let report = run_steal(
                    &im,
                    &cmm,
                    Topology::default().serialize(),
                    n,
                    tasks,
                    Arc::clone(&sys),
                    StealConfig::default(),
                    |_| 0,
                )
                .unwrap();
                sys.shutdown().unwrap();
                report
            }));
        }
        let report = joins
            .into_iter()
            .filter_map(|j| j.join().unwrap())
            .next()
            .expect("root produced a report");
        assert_eq!(report.world, 4);
        assert_eq!(report.workers, 3);
        assert_eq!(report.tasks, 60);
        // Zero lost, zero duplicated: every task verified exactly once.
        assert_eq!(report.local_tasks + report.stolen_tasks, 60);
        let want: u64 = (0..60).map(task_value).fold(0, u64::wrapping_add);
        assert_eq!(report.checksum, want);
        // The imbalance was actually drained by thieves, lazily.
        assert!(report.stolen_tasks > 0, "{report:?}");
        let per: u64 = report.per_worker.iter().map(|(_, c)| c).sum();
        assert_eq!(per, report.stolen_tasks);
        assert!(report.lazy_payload_bytes > 0, "{report:?}");
        assert_eq!(report.spilled_tasks, 0);
        // No crashes in this world → nothing recovered (and the
        // supervised drain over a detector-less backend is a no-op).
        assert_eq!(report.recovered, 0);
    }

    #[test]
    fn chaos_mode_parses_cli_spelling() {
        assert_eq!(ChaosMode::parse("kill-one").unwrap(), ChaosMode::KillOne);
        assert!(ChaosMode::parse("kill-two").is_err());
    }
}
