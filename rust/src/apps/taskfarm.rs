//! Master/worker task farm over the deployment mesh (the paper's Fig. 7
//! orchestration pattern as a runnable distributed app).
//!
//! Every instance enters [`run`]: the root ensures the world holds
//! `total` instances (spawning the difference at runtime through the
//! instance manager — the elastic ramp-up), all instances join the
//! deployment mesh, workers register the farmed function and serve,
//! while the root gathers all worker topologies via the built-in
//! `topology` RPC, dispatches `tasks` tasks round-robin across the
//! workers, verifies every result, and shuts the farm down by RPC.
//!
//! Written purely against the abstract managers and the deployment/RPC
//! frontends: the same code farms over the threads backend (in-process)
//! and over mpisim (real processes launched by `hicr launch`).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::core::communication::CommunicationManager;
use crate::core::error::{HicrError, Result};
use crate::core::ids::MemorySpaceId;
use crate::core::instance::{InstanceManager, InstanceTemplate};
use crate::core::memory::LocalMemorySlot;
use crate::core::topology::{Topology, TopologyRequirements};
use crate::frontends::deployment::{deploy, Deployment, DeploymentConfig};

/// The farmed RPC.
pub const FN_TASK: &str = "taskfarm/execute";

/// The task kernel: a splitmix64 avalanche of the task index — cheap,
/// deterministic, and sensitive to any payload corruption, so the root
/// can verify every single result.
pub fn task_value(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// What the root observed (workers return `None`).
#[derive(Debug, Clone)]
pub struct FarmReport {
    pub world: usize,
    pub workers: usize,
    pub tasks: u64,
    /// Tasks executed per worker rank.
    pub per_worker: Vec<(u32, u64)>,
    /// Wrapping sum of all verified results.
    pub checksum: u64,
    /// Worker topologies gathered through the built-in RPC.
    pub gathered_topologies: usize,
    /// Devices across all gathered topologies.
    pub total_devices: usize,
    pub elapsed_s: f64,
}

/// Run this instance's side of the farm. Collective across the world:
/// root returns `Some(report)`, workers serve until shutdown and return
/// `None`. `topology_json` is this instance's serialized device tree.
pub fn run(
    im: &dyn InstanceManager,
    cmm: &Arc<dyn CommunicationManager>,
    topology_json: String,
    total: usize,
    tasks: u64,
) -> Result<Option<FarmReport>> {
    let t0 = Instant::now();
    let alloc = |len| LocalMemorySlot::alloc(MemorySpaceId(1), len);
    let template = InstanceTemplate::new(TopologyRequirements::default());
    let mut d = deploy(
        im,
        cmm,
        total,
        &template,
        &DeploymentConfig::default(),
        topology_json,
        alloc,
    )?;

    if !d.is_root {
        d.mesh.server.register(FN_TASK, |args| {
            let x = u64::from_le_bytes(args.try_into().map_err(|_| {
                HicrError::Bounds("taskfarm payload must be 8 B".into())
            })?);
            Ok(task_value(x).to_le_bytes().to_vec())
        })?;
        d.serve_until_shutdown()?;
        // Exit in lockstep with the root's post-shutdown barrier.
        im.barrier()?;
        return Ok(None);
    }

    match orchestrate(&mut d, tasks) {
        Ok((topos, total_devices, per_worker, checksum)) => {
            d.shutdown_workers()?;
            im.barrier()?;
            Ok(Some(FarmReport {
                world: d.ranks.len(),
                workers: d.workers().len(),
                tasks,
                per_worker: per_worker.into_iter().collect(),
                checksum,
                gathered_topologies: topos.len(),
                total_devices,
                elapsed_s: t0.elapsed().as_secs_f64(),
            }))
        }
        Err(e) => {
            // Best-effort release: without this, live workers would sit
            // in their serve loops forever and the launcher would hang
            // instead of reporting the orchestration error. (A worker
            // that died mid-farm can still stall its own shutdown call;
            // per-call deadlines are future work.)
            if d.shutdown_workers().is_ok() {
                let _ = im.barrier();
            }
            Err(e)
        }
    }
}

type Orchestrated = (Vec<(u32, Topology)>, usize, BTreeMap<u32, u64>, u64);

/// The root's orchestration body, separated so `run` can release the
/// workers on *any* error path.
fn orchestrate(d: &mut Deployment, tasks: u64) -> Result<Orchestrated> {
    let topos = d.gather_topologies()?;
    let total_devices = topos.iter().map(|(_, t)| t.devices.len()).sum();
    let workers = d.workers();
    if workers.is_empty() {
        return Err(HicrError::Instance(
            "taskfarm needs at least one worker (launch with --np 2 or more)"
                .into(),
        ));
    }
    let mut per_worker: BTreeMap<u32, u64> =
        workers.iter().map(|&w| (w, 0)).collect();
    let mut checksum = 0u64;
    for i in 0..tasks {
        let w = workers[(i % workers.len() as u64) as usize];
        let ret = d.client(w)?.call(FN_TASK, &i.to_le_bytes())?;
        let got =
            u64::from_le_bytes(ret.as_slice().try_into().map_err(|_| {
                HicrError::Transport(format!(
                    "task {i}: short response ({} B) from worker {w}",
                    ret.len()
                ))
            })?);
        let want = task_value(i);
        if got != want {
            return Err(HicrError::InvalidState(format!(
                "task {i} on worker {w}: got {got:#018x}, want {want:#018x}"
            )));
        }
        checksum = checksum.wrapping_add(got);
        *per_worker.get_mut(&w).expect("dispatched to a known worker") += 1;
    }
    Ok((topos, total_devices, per_worker, checksum))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::threads::ThreadsCommunicationManager;
    use crate::core::instance::testworld::local_world;

    #[test]
    fn task_value_deterministic_and_mixing() {
        assert_eq!(task_value(7), task_value(7));
        assert_ne!(task_value(7), task_value(8));
        assert_ne!(task_value(0), 0);
    }

    /// Full farm over the threads backend: 1 root + 2 workers in one
    /// process, 31 tasks (odd count → uneven round-robin) all verified.
    #[test]
    fn farm_in_process_three_instances() {
        let n = 3usize;
        let cmm: Arc<dyn CommunicationManager> =
            Arc::new(ThreadsCommunicationManager::new());
        let mut joins = Vec::new();
        for im in local_world(n) {
            let cmm = Arc::clone(&cmm);
            joins.push(std::thread::spawn(move || {
                run(&im, &cmm, Topology::default().serialize(), n, 31).unwrap()
            }));
        }
        let reports: Vec<_> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        let report = reports
            .iter()
            .flatten()
            .next()
            .expect("root produced a report");
        assert_eq!(report.world, 3);
        assert_eq!(report.workers, 2);
        assert_eq!(report.tasks, 31);
        assert_eq!(report.gathered_topologies, 2);
        let per: u64 = report.per_worker.iter().map(|(_, c)| c).sum();
        assert_eq!(per, 31);
        assert_eq!(report.per_worker[0].1, 16); // rank 1 gets the extra task
        assert_eq!(report.per_worker[1].1, 15);
        let want: u64 = (0..31).map(task_value).fold(0, u64::wrapping_add);
        assert_eq!(report.checksum, want);
    }
}
