"""Synthetic MNIST-like dataset (deterministic).

The paper's Test Case 2 trains an MLP on MNIST and runs inference across
HiCR backends. The sandbox has no network access, so MNIST itself is not
available; per the reproduction substitution rule we generate a
deterministic MNIST-*shaped* dataset: 28x28 grayscale digit images in 10
classes, built by rasterizing coarse glyph templates with random affine
jitter (shift/scale), stroke-thickness variation and additive noise.

The task difficulty is tuned so a small MLP lands in the low-to-mid 90%
accuracy band, matching the paper's 94.64% headline closely enough that the
cross-backend consistency comparison (Table 2) is meaningful.
"""

from __future__ import annotations

import numpy as np

IMG = 28
N_CLASSES = 10

# 7x5 coarse glyph templates for digits 0-9 (classic 5x7 font, rows of 5 bits).
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _template(digit: int) -> np.ndarray:
    """Return the 7x5 float template for a digit."""
    rows = _GLYPHS[digit]
    return np.array([[float(c) for c in row] for row in rows], dtype=np.float32)


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Rasterize one jittered 28x28 image of `digit` in [0, 1]."""
    tmpl = _template(digit)  # (7, 5)
    # Random target size (stroke scale) and position.
    sh = int(rng.integers(14, 22))  # glyph height in pixels
    sw = int(rng.integers(10, 16))  # glyph width in pixels
    # Nearest-neighbour upscale of the template to (sh, sw).
    yi = (np.arange(sh) * tmpl.shape[0] / sh).astype(np.int32)
    xi = (np.arange(sw) * tmpl.shape[1] / sw).astype(np.int32)
    glyph = tmpl[yi][:, xi]
    # Light blur to soften edges (3x3 box filter, zero padded).
    padded = np.pad(glyph, 1)
    blurred = sum(
        padded[dy : dy + sh, dx : dx + sw] for dy in range(3) for dx in range(3)
    ) / 9.0
    glyph = np.clip(glyph * 0.7 + blurred * 0.6, 0.0, 1.0)
    # Place at a random offset.
    img = np.zeros((IMG, IMG), dtype=np.float32)
    oy = int(rng.integers(0, IMG - sh + 1))
    ox = int(rng.integers(0, IMG - sw + 1))
    img[oy : oy + sh, ox : ox + sw] = glyph
    # Intensity jitter + noise; this is what keeps the task from being
    # trivially separable (pushing accuracy into the ~90s band).
    img *= rng.uniform(0.6, 1.0)
    img += rng.normal(0.0, 0.18, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def make_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` (image, label) pairs.

    Returns (x, y) where x is float32 (n, 784) in [0,1] and y is uint8 (n,).
    Deterministic for a given (n, seed).
    """
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.uint8)
    imgs = np.stack([_render(int(d), rng) for d in labels])
    return imgs.reshape(n, IMG * IMG).astype(np.float32), labels


def train_test_split(
    n_train: int = 12000, n_test: int = 10000, seed: int = 7
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """The canonical dataset used by train.py and aot.py.

    Train and test use disjoint seeds so the test set is held out.
    """
    x_tr, y_tr = make_dataset(n_train, seed)
    x_te, y_te = make_dataset(n_test, seed + 1000003)
    return x_tr, y_tr, x_te, y_te
