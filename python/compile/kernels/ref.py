"""Pure-jnp oracle for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest (python/tests/test_kernel.py)
sweeps shapes/dtypes with hypothesis and asserts the kernel matches the
oracle — this is the core L1 correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp


def dense_ref(x, w, b, activation: str = "none"):
    """Reference dense layer: activation(x @ w + b).

    x: (M, K), w: (K, N), b: (N,). Accumulation in float32 regardless of
    input dtype, output cast back to x.dtype — mirroring the kernel's
    MXU-style fp32 accumulate.
    """
    y = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    ) + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


def mlp_ref(params, x):
    """Reference MLP forward: relu-dense layers with a linear head."""
    h = x
    for i, (w, b) in enumerate(params):
        act = "none" if i == len(params) - 1 else "relu"
        h = dense_ref(h, w, b, act)
    return h
