"""L1 Pallas kernels + pure-jnp reference oracles."""

from .dense import dense, vmem_footprint  # noqa: F401
from .ref import dense_ref, mlp_ref  # noqa: F401
