"""L1 Pallas kernel: tiled dense layer (matmul + bias + activation).

This is the compute hot-spot of the paper's Test Case 2 inference pipeline
(the ACL / OpenCL device kernels of the original), re-thought for a TPU-
class device per the hardware-adaptation rule:

- The grid tiles (M, N, K) into MXU-friendly blocks. BlockSpec expresses
  the HBM -> VMEM schedule that the paper's GPU/NPU kernels expressed with
  threadblocks/streams.
- Accumulation happens in float32 directly in the output block (the output
  block for a given (i, j) stays resident in VMEM across the K grid
  dimension), mirroring an MXU fp32 accumulator.
- Bias add + activation are fused into the final K step, so the activation
  never round-trips through HBM.

The kernel MUST run with interpret=True in this environment: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
Structure (tile sizes, VMEM footprint) is still chosen as if for a real
TPU; see DESIGN.md §Perf for the footprint analysis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128x128 matches the MXU systolic array; the K tile is
# chosen so one (bm x bk) + (bk x bn) + (bm x bn) working set stays well
# under a 16 MiB VMEM budget (see vmem_footprint()).
BM, BN, BK = 128, 128, 128


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: str):
    """Grid point (i, j, k): o[i,j] += x[i,k] @ w[k,j]; finalize at k==nk-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finalize():
        y = o_ref[...] + b_ref[...]
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


def _pad_to(a, axis: int, mult: int):
    """Zero-pad `a` along `axis` up to the next multiple of `mult`."""
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "interpret")
)
def dense(
    x,
    w,
    b,
    activation: str = "none",
    *,
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
    interpret: bool = True,
):
    """Pallas tiled dense layer: activation(x @ w + b).

    x: (M, K), w: (K, N), b: (N,). Arbitrary M/K/N are supported by
    zero-padding each dimension up to the tile multiple and slicing the
    result; zero padding is exact for matmul + bias and for relu.
    Accumulates in float32 and casts back to x.dtype.
    """
    if activation not in ("none", "relu"):
        raise ValueError(f"unknown activation {activation!r}")
    m, kdim = x.shape
    k2, n = w.shape
    if kdim != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    out_dtype = x.dtype

    # Clamp tiles to the (padded) problem so tiny layers don't blow up the
    # grid with fully-padded blocks.
    bm = min(bm, _ceil_mult(m, 8))
    bn = min(bn, _ceil_mult(n, 8))
    bk = min(bk, _ceil_mult(kdim, 8))

    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w.astype(jnp.float32), 0, bk), 1, bn)
    bp = _pad_to(b.astype(jnp.float32).reshape(1, n), 1, bn)

    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_dense_kernel, nk=grid[2], activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)

    return out[:m, :n].astype(out_dtype)


def _ceil_mult(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def vmem_footprint(bm: int = BM, bn: int = BN, bk: int = BK) -> int:
    """Bytes of VMEM resident per grid point (f32): x, w, bias, out blocks.

    With the defaults: (128*128 + 128*128 + 128 + 128*128) * 4 B ~= 197 KiB,
    i.e. <2% of a 16 MiB VMEM — leaving ample room for double buffering of
    the x/w streams (the interpreter does not model this, a real Mosaic
    lowering would).
    """
    return 4 * (bm * bk + bk * bn + bn + bm * bn)
