"""Build-time training of the Test Case 2 MLP (pure JAX, never shipped).

Trains on the synthetic MNIST-like dataset with mini-batch SGD + momentum.
Training uses the *reference* forward pass (fast XLA path); the Pallas
kernel path is what gets AOT-exported for inference — tests assert the two
agree, mirroring the paper's setup where training happened offline and
only inference runs through HiCR backends.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import LAYER_DIMS, accuracy, forward_ref, init_params


def _loss(params, x, y):
    logits = forward_ref(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1))


@jax.jit
def _step(params, velocity, x, y, lr, momentum):
    loss, grads = jax.value_and_grad(_loss)(params, x, y)
    new_v = jax.tree_util.tree_map(lambda v, g: momentum * v - lr * g, velocity, grads)
    new_p = jax.tree_util.tree_map(lambda p, v: p + v, params, new_v)
    return new_p, new_v, loss


def train(
    n_train: int = 12000,
    n_test: int = 10000,
    epochs: int = 12,
    batch: int = 128,
    lr: float = 0.08,
    momentum: float = 0.9,
    seed: int = 7,
    verbose: bool = True,
):
    """Train the MLP; returns (params, test_accuracy, history)."""
    x_tr, y_tr, x_te, y_te = data.train_test_split(n_train, n_test, seed)
    params = init_params(seed, LAYER_DIMS)
    velocity = jax.tree_util.tree_map(jnp.zeros_like, params)
    rng = np.random.default_rng(seed)
    history = []
    t0 = time.time()
    for epoch in range(epochs):
        perm = rng.permutation(n_train)
        losses = []
        for i in range(0, n_train - batch + 1, batch):
            idx = perm[i : i + batch]
            params, velocity, loss = _step(
                params, velocity, x_tr[idx], y_tr[idx], lr, momentum
            )
            losses.append(float(loss))
        epoch_loss = float(np.mean(losses))
        history.append(epoch_loss)
        if verbose:
            print(
                f"[train] epoch {epoch + 1:2d}/{epochs} "
                f"loss={epoch_loss:.4f} ({time.time() - t0:.1f}s)"
            )
    # Final held-out accuracy through the *reference* path; the Pallas path
    # is asserted equal in tests and re-measured by the Rust benches.
    logits = forward_ref(params, x_te)
    test_acc = float(jnp.mean((jnp.argmax(logits, axis=-1) == y_te).astype(jnp.float32)))
    if verbose:
        print(f"[train] test accuracy (ref path) = {test_acc * 100:.2f}%")
    return params, test_acc, history


if __name__ == "__main__":
    train()
