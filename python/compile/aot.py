"""AOT export: train the MLP once and lower the Pallas-backed forward pass
to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT jax .serialize(): the xla crate's
bundled xla_extension 0.5.1 rejects jax>=0.5 serialized HloModuleProto
(64-bit instruction ids); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  mlp_b{B}.hlo.txt   lowered forward pass per batch size B (tuple output)
  weights.bin        little-endian f32 concat of w1,b1,w2,b2,w3,b3
  testset.bin        f32 images (n,784) followed by u8 labels (n,)
  meta.json          shapes/offsets/batch sizes/expected scores

Run via `make artifacts`; it is a no-op if artifacts are newer than the
python/compile sources.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .model import LAYER_DIMS, flat_forward, forward_ref
from .train import train

BATCH_SIZES = (1, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_forward(flat_params, batch: int) -> str:
    """Lower flat_forward for a fixed batch size to HLO text."""
    x_spec = jax.ShapeDtypeStruct((batch, LAYER_DIMS[0]), jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat_params]

    def fn(x, *ps):
        return (flat_forward(x, *ps),)

    lowered = jax.jit(fn).lower(x_spec, *p_specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--n-train", type=int, default=12000)
    ap.add_argument("--n-test", type=int, default=10000)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    # 1. Train (build-time only).
    params, test_acc, _ = train(
        n_train=args.n_train, n_test=args.n_test, epochs=args.epochs, seed=args.seed
    )
    flat = [np.asarray(t) for wb in params for t in wb]

    # 2. Weights blob.
    weights_path = os.path.join(args.out_dir, "weights.bin")
    offsets = []
    with open(weights_path, "wb") as f:
        for t in flat:
            offsets.append({"shape": list(t.shape), "offset": f.tell()})
            f.write(np.ascontiguousarray(t, dtype="<f4").tobytes())

    # 3. Test set blob (same one the Rust benches score — Table 2).
    _, _, x_te, y_te = data.train_test_split(args.n_train, args.n_test, args.seed)
    testset_path = os.path.join(args.out_dir, "testset.bin")
    with open(testset_path, "wb") as f:
        f.write(np.ascontiguousarray(x_te, dtype="<f4").tobytes())
        f.write(np.ascontiguousarray(y_te, dtype=np.uint8).tobytes())

    # 4. HLO artifacts per batch size.
    hlo_files = {}
    for b in BATCH_SIZES:
        text = lower_forward(flat, b)
        name = f"mlp_b{b}.hlo.txt"
        with open(os.path.join(args.out_dir, name), "w") as f:
            f.write(text)
        hlo_files[str(b)] = name
        print(f"[aot] wrote {name} ({len(text)} chars)")

    # 5. Reference img-0 score (Table 2's precision-comparison column),
    #    computed with the plain-jnp oracle.
    logits0 = np.asarray(forward_ref(params, x_te[:1]))[0]
    img0_score = float(np.max(logits0))
    img0_pred = int(np.argmax(logits0))

    meta = {
        "layer_dims": list(LAYER_DIMS),
        "batch_sizes": list(BATCH_SIZES),
        "hlo": hlo_files,
        "weights": {"file": "weights.bin", "tensors": offsets},
        "testset": {
            "file": "testset.bin",
            "n": int(x_te.shape[0]),
            "img_dim": int(x_te.shape[1]),
        },
        "train": {
            "n_train": args.n_train,
            "epochs": args.epochs,
            "seed": args.seed,
            "ref_test_accuracy": test_acc,
        },
        "img0": {"score": img0_score, "pred": img0_pred},
    }
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] ref accuracy {test_acc * 100:.2f}%, img0 score {img0_score:.9f}")


if __name__ == "__main__":
    main()
