"""L2 JAX model: the Test Case 2 MLP classifier (784 -> 256 -> 128 -> 10).

The forward pass calls the L1 Pallas dense kernel for every layer, so the
whole network lowers into a single HLO module that the Rust runtime
executes via PJRT. Weights are *arguments* of the lowered function (not
baked-in constants): the Rust side loads artifacts/weights.bin and passes
them per call — the serving path can hot-swap weights without recompiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import dense, mlp_ref

LAYER_DIMS = (784, 256, 128, 10)


def init_params(seed: int, dims=LAYER_DIMS):
    """He-initialized MLP parameters as a list of (w, b) pairs (float32)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for din, dout in zip(dims[:-1], dims[1:]):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (din, dout), jnp.float32) * jnp.sqrt(2.0 / din)
        params.append((w, jnp.zeros((dout,), jnp.float32)))
    return params


def forward(params, x, *, interpret: bool = True):
    """Pallas-backed forward pass: relu hidden layers, linear head.

    x: (batch, 784) float32 -> logits (batch, 10) float32.
    """
    h = x
    for i, (w, b) in enumerate(params):
        act = "none" if i == len(params) - 1 else "relu"
        h = dense(h, w, b, act, interpret=interpret)
    return h


def forward_ref(params, x):
    """Oracle forward pass (plain jnp) — used in tests and for Table 2's
    'ad-hoc non-HiCR baseline' score verification."""
    return mlp_ref(params, x)


def flat_forward(x, *flat_params, interpret: bool = True):
    """forward() with params flattened to (w1, b1, w2, b2, ...) — the
    signature that aot.py lowers, matching the Rust runtime's calling
    convention: [input, w1, b1, w2, b2, w3, b3]."""
    assert len(flat_params) % 2 == 0
    params = [
        (flat_params[i], flat_params[i + 1]) for i in range(0, len(flat_params), 2)
    ]
    return forward(params, x, interpret=interpret)


def predict(params, x):
    """Class predictions via the Pallas forward pass."""
    return jnp.argmax(forward(params, x), axis=-1)


def accuracy(params, x, y) -> float:
    """Mean accuracy of the Pallas forward pass on (x, y)."""
    return float(jnp.mean((predict(params, x) == y).astype(jnp.float32)))
