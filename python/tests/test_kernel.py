"""L1 correctness: Pallas dense kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/activations/tile sizes; every case asserts
allclose against ref.dense_ref. This is the core kernel signal required
before anything is AOT-exported.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import dense, dense_ref, vmem_footprint

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=40, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _case(m, k, n, dtype, act, bm=128, bn=128, bk=128, rtol=None):
    key = jax.random.PRNGKey(m * 10007 + k * 101 + n)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (m, k), dtype)
    w = _rand(k2, (k, n), dtype)
    b = _rand(k3, (n,), dtype)
    got = dense(x, w, b, act, bm=bm, bn=bn, bk=bk)
    want = dense_ref(x, w, b, act)
    assert got.shape == want.shape and got.dtype == want.dtype
    if rtol is None:
        rtol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=rtol, atol=1e-4
    )


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(["none", "relu"]),
)
def test_small_shapes_f32(m, k, n, act):
    """Arbitrary small shapes (exercises the padding path heavily)."""
    _case(m, k, n, jnp.float32, act)


@given(
    m=st.sampled_from([1, 32, 128, 256]),
    k=st.sampled_from([128, 256, 384, 784]),
    n=st.sampled_from([10, 128, 256]),
)
def test_tile_multiples_and_model_shapes(m, k, n):
    """The shapes the MLP actually uses, plus exact tile multiples."""
    _case(m, k, n, jnp.float32, "relu")


@given(
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 64, 128]),
    bk=st.sampled_from([8, 16, 128]),
)
def test_tile_size_sweep(bm, bn, bk):
    """Result must be independent of the BlockSpec tiling."""
    _case(48, 100, 36, jnp.float32, "relu", bm=bm, bn=bn, bk=bk)


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 64),
    n=st.integers(1, 40),
)
def test_bfloat16(m, k, n):
    """bf16 inputs, fp32 accumulate — the MXU-native dtype path."""
    _case(m, k, n, jnp.bfloat16, "relu", rtol=8e-2)


def test_activation_validation():
    x = jnp.zeros((4, 4))
    with pytest.raises(ValueError):
        dense(x, jnp.zeros((4, 4)), jnp.zeros((4,)), "gelu")


def test_shape_validation():
    with pytest.raises(ValueError):
        dense(jnp.zeros((4, 5)), jnp.zeros((4, 4)), jnp.zeros((4,)))


def test_zero_inputs_relu_boundary():
    """relu at exactly zero: padding must not flip signs."""
    x = jnp.zeros((3, 7))
    w = jnp.zeros((7, 5))
    b = jnp.array([-1.0, 0.0, 1.0, -0.5, 0.5])
    got = np.asarray(dense(x, w, b, "relu"))
    want = np.maximum(np.asarray(b), 0.0)
    np.testing.assert_allclose(got, np.tile(want, (3, 1)))


def test_vmem_footprint_budget():
    """Default tiling stays far below a 16 MiB VMEM budget."""
    assert vmem_footprint() < 16 * 1024 * 1024 // 8


def test_large_single_tile_exceeds_naive_but_fits_blocked():
    """A 1024-wide layer still evaluates correctly with default 128 tiles."""
    _case(8, 1024, 512, jnp.float32, "relu")
