"""AOT path smoke: HLO text emission, parseability markers, weight blob
layout — everything the Rust runtime depends on."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import lower_forward, to_hlo_text
from compile.model import LAYER_DIMS, init_params


def _flat_params(seed=0):
    return [np.asarray(t) for wb in init_params(seed) for t in wb]


def test_hlo_text_structure():
    flat = _flat_params()
    text = lower_forward(flat, batch=1)
    # The Rust loader requires parseable HLO text with an ENTRY computation.
    assert "ENTRY" in text
    assert "HloModule" in text
    # One parameter per weight tensor + the input — counted in the ENTRY
    # computation only (pallas_call sub-computations re-declare their own).
    entry = text[text.index("ENTRY") :]
    entry_block = entry.split("\n\n")[0]
    n_params = entry_block.count("parameter(")
    assert n_params == len(flat) + 1, f"expected {len(flat) + 1} params, got {n_params}"


def test_hlo_text_batch32_differs():
    flat = _flat_params()
    t1 = lower_forward(flat, batch=1)
    t32 = lower_forward(flat, batch=32)
    assert "f32[32,784]" in t32
    assert "f32[1,784]" in t1


def test_to_hlo_text_return_tuple():
    """Outputs must be a 1-tuple (Rust unwraps with to_tuple1)."""

    def fn(x):
        return (x * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = to_hlo_text(lowered)
    assert "tuple" in text.lower() or "(f32[2,2]" in text


def test_end_to_end_aot_tiny(tmp_path):
    """Full aot.py run with a tiny config; validates every artifact file."""
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(tmp_path),
            "--epochs",
            "1",
            "--n-train",
            "600",
            "--n-test",
            "200",
        ],
        cwd=repo_py,
        env=env,
        check=True,
        timeout=600,
    )
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["layer_dims"] == list(LAYER_DIMS)
    # Weights blob size == sum of tensor sizes.
    total = sum(
        int(np.prod(t["shape"])) for t in meta["weights"]["tensors"]
    )
    assert (tmp_path / "weights.bin").stat().st_size == total * 4
    # Test set blob: n*(784*4 + 1) bytes.
    n = meta["testset"]["n"]
    assert (tmp_path / "testset.bin").stat().st_size == n * (784 * 4 + 1)
    for name in meta["hlo"].values():
        assert (tmp_path / name).stat().st_size > 1000
    assert 0.0 <= meta["train"]["ref_test_accuracy"] <= 1.0
