"""L2 correctness: MLP forward (Pallas path) vs reference, data pipeline
determinism, and a fast training smoke gate."""

import jax.numpy as jnp
import numpy as np

from compile import data
from compile.model import (
    LAYER_DIMS,
    accuracy,
    flat_forward,
    forward,
    forward_ref,
    init_params,
)


def test_forward_shapes():
    params = init_params(0)
    for batch in (1, 3, 32):
        x = jnp.ones((batch, LAYER_DIMS[0]))
        out = forward(params, x)
        assert out.shape == (batch, LAYER_DIMS[-1])
        assert out.dtype == jnp.float32


def test_pallas_matches_reference():
    """The exported (Pallas) path must agree with the jnp oracle."""
    params = init_params(3)
    x = jnp.asarray(data.make_dataset(16, seed=5)[0])
    np.testing.assert_allclose(
        np.asarray(forward(params, x)),
        np.asarray(forward_ref(params, x)),
        rtol=2e-5,
        atol=1e-5,
    )


def test_flat_forward_matches_forward():
    params = init_params(1)
    flat = [t for wb in params for t in wb]
    x = jnp.asarray(data.make_dataset(4, seed=9)[0])
    np.testing.assert_allclose(
        np.asarray(flat_forward(x, *flat)), np.asarray(forward(params, x)), rtol=1e-6
    )


def test_dataset_deterministic_and_disjoint():
    x1, y1 = data.make_dataset(64, seed=11)
    x2, y2 = data.make_dataset(64, seed=11)
    x3, _ = data.make_dataset(64, seed=12)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert not np.array_equal(x1, x3)
    assert x1.shape == (64, 784) and x1.dtype == np.float32
    assert x1.min() >= 0.0 and x1.max() <= 1.0
    assert set(np.unique(y1)) <= set(range(10))


def test_training_smoke():
    """A few epochs on a small slice must beat chance by a wide margin."""
    from compile.train import train

    params, acc, history = train(
        n_train=3000, n_test=600, epochs=5, batch=128, verbose=False
    )
    assert acc > 0.5, f"training failed to learn: acc={acc}"
    assert history[-1] < history[0], "loss did not decrease"


def test_accuracy_helper_consistent():
    params = init_params(2)
    x, y = data.make_dataset(32, seed=21)
    acc = accuracy(params, jnp.asarray(x), jnp.asarray(y))
    assert 0.0 <= acc <= 1.0
